"""Tile-budget autotuner: joint (quantile, DoP, partition-count) search.

The paper's headline resource result — up to ~32 % fewer tiles than
work-conserving baselines at the same service level — comes from
searching colocation and DoP *jointly* under the shared E2E deadlines,
not from walking one knob at a time.  The original portfolio compile
did the latter: a one-dimensional q-relaxation ladder at a fixed
partition count, keeping the most conservative deadline-feasible
quantile per mode.  This module replaces it with a joint search:

* **Quantile axis** — the q grid of Eq. (1) bounds (the paper's §V-B
  guideline: relax q under pressure, tail-composition headroom covers
  the difference).
* **Spatial axis** — candidate partition counts around the compiler's
  default (ADS-Tile's configurable isolation domains) and a sweep of
  *tile budgets* below the full chip (``GHACompiler.tile_budget``),
  which squeezes the per-task DoPs through the compiler's own
  compaction machinery.
* **Pruning** — candidate (q, partition) cells are discarded without
  compiling when even the latency-minimal DoP assignment cannot meet a
  chain deadline; the check runs on the cached
  :meth:`~repro.core.latency_model.LatencyModel.bound_ladder`.

Every surviving compile becomes a :class:`FrontierPoint` carrying the
tiles it reserves and its *predicted E2E miss probability* (an
analytic per-chain bound, see :func:`predict_miss`).  A mode's
:class:`ModeFrontier` exposes the Pareto-optimal subset — more tiles
never buys a worse predicted miss on the frontier by construction —
and :meth:`ModeFrontier.select` picks the cheapest point meeting a
target miss probability (or, with no target, the most conservative
feasible point, which reproduces the legacy q-ladder choice exactly
when the partition count is pinned).
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ...obs import metrics
from ..gha.compiler import GHACompiler
from ..gha.schedule import Schedule
from ..latency_model import LatencyModel
from ..workload import Workflow

__all__ = [
    "FrontierPoint",
    "ModeFrontier",
    "autotune_mode",
    "predict_miss",
    "clear_frontier_cache",
]

#: bisection bracket for the per-chain composed quantile q* — below
#: 0.5 a schedule is useless (misses most deadlines), above ~0.9999
#: the lognormal tails stop moving within float resolution
_Q_LO = 0.5
_Q_HI = 0.9999
_Q_ITERS = 40


@dataclasses.dataclass(frozen=True, eq=False)
class FrontierPoint:
    """One compiled operating point of a mode.

    ``tiles`` is what the schedule actually reserves
    (``Schedule.peak_tiles``); ``miss`` is the analytic upper bound on
    the E2E deadline-miss probability (:func:`predict_miss`);
    ``feasible`` mirrors the compiler's own flags (no Phase-I
    infeasible chain, no Phase-III deadline violation).
    """

    tiles: int
    miss: float
    q: float
    num_partitions: int
    budget: int
    feasible: bool
    schedule: Schedule

    def key(self) -> Tuple[int, float, float, int]:
        return (self.tiles, self.miss, self.q, self.num_partitions)


@dataclasses.dataclass
class ModeFrontier:
    """All operating points explored for one driving mode."""

    mode: str
    points: List[FrontierPoint]

    def feasible_points(self) -> List[FrontierPoint]:
        return [p for p in self.points if p.feasible]

    def partition_counts(self) -> Tuple[int, ...]:
        return tuple(sorted({p.num_partitions for p in self.points}))

    def pareto(self) -> List[FrontierPoint]:
        """Non-dominated feasible points, cheapest first.

        Sorted by tiles ascending; a point survives only if its
        predicted miss is strictly below every cheaper survivor's, so
        the returned frontier is monotone: more tiles never increases
        the predicted miss probability.
        """
        best = math.inf
        out: List[FrontierPoint] = []
        for p in sorted(self.feasible_points(), key=lambda p: (p.tiles, p.miss)):
            if p.miss < best - 1e-15:
                out.append(p)
                best = p.miss
        return out

    def select(
        self,
        target_miss: Optional[float] = None,
        num_partitions: Optional[int] = None,
    ) -> FrontierPoint:
        """Pick the operating point the portfolio should install.

        With ``target_miss`` set: the fewest-tiles feasible point whose
        predicted miss meets the target (ties prefer the higher
        quantile); if no point meets it, the lowest-miss feasible
        point.  With no target: the most conservative feasible point —
        highest quantile, then lowest predicted miss, then fewest
        tiles — which is exactly the schedule the legacy q-relaxation
        ladder kept.  When nothing is feasible the ladder's fallback
        applies: the lowest-quantile compile.  ``num_partitions``
        restricts the choice to one spatial configuration (hot-swap
        compatibility requires every mode of a portfolio to share it).
        """
        pts = [
            p
            for p in self.points
            if num_partitions is None or p.num_partitions == num_partitions
        ]
        if not pts:
            raise ValueError(
                f"{self.mode}: no frontier point at {num_partitions} partitions"
            )
        feas = [p for p in pts if p.feasible]
        if not feas:
            return min(pts, key=lambda p: (p.q, p.miss, p.tiles))
        if target_miss is None:
            q_max = max(p.q for p in feas)
            top = [p for p in feas if p.q == q_max]
            return min(top, key=lambda p: (p.miss, p.tiles))
        within = [p for p in feas if p.miss <= target_miss]
        if within:
            return min(within, key=lambda p: (p.tiles, -p.q, p.miss))
        return min(feas, key=lambda p: (p.miss, p.tiles))

    def select_within_tiles(
        self,
        max_tiles: int,
        target_miss: Optional[float] = None,
    ) -> Optional[FrontierPoint]:
        """Degraded-budget selection: the best operating point whose
        reservation fits ``max_tiles`` — what an online replanner swaps
        to when tiles die (``docs/degradation.md``).  Any partition
        count qualifies (the engine morphs partitions online), feasible
        points meeting ``target_miss`` win on fewest tiles, then
        feasible points on lowest predicted miss, then infeasible ones
        as a last resort.  ``None`` when nothing fits the budget."""
        pts = [p for p in self.points if p.tiles <= max_tiles]
        if not pts:
            return None
        feas = [p for p in pts if p.feasible]
        if not feas:
            return min(pts, key=lambda p: (p.miss, p.tiles, -p.q))
        if target_miss is not None:
            within = [p for p in feas if p.miss <= target_miss]
            if within:
                return min(within, key=lambda p: (p.tiles, -p.q, p.miss))
        return min(feas, key=lambda p: (p.miss, p.tiles, -p.q))

    def blend_source(
        self, num_partitions: int, selected: FrontierPoint
    ) -> Optional[FrontierPoint]:
        """The most conservative feasible point at ``num_partitions``
        if it is more conservative than ``selected`` — the transition
        hedge draws per-task plans from it so a budget-tightened
        portfolio still hedges with the high-quantile plan while the
        context is ambiguous.  ``None`` when ``selected`` is already
        the most conservative choice."""
        feas = [
            p
            for p in self.feasible_points()
            if p.num_partitions == num_partitions
        ]
        if not feas:
            return None
        best = min(feas, key=lambda p: (-p.q, p.miss, p.tiles))
        if best is selected or best.q <= selected.q:
            return None
        return best

    def meta(self, selected: FrontierPoint) -> Dict[str, object]:
        """The ``Schedule.meta["autotune"]`` payload for ``selected``."""
        return {
            "q": selected.q,
            "tiles": selected.tiles,
            "predicted_miss": selected.miss,
            "num_partitions": selected.num_partitions,
            "budget": selected.budget,
            "frontier": [
                (p.tiles, p.miss, p.q, p.num_partitions) for p in self.pareto()
            ],
        }


# ---------------------------------------------------------------------------
# predicted E2E miss probability
# ---------------------------------------------------------------------------
def _chain_miss(
    model: LatencyModel,
    wf: Workflow,
    nodes: Tuple[str, ...],
    dops: np.ndarray,
    deadline_s: float,
) -> float:
    """Analytic miss bound for one chain under fixed DoPs.

    Finds (by bisection) the largest composed quantile q* at which the
    sum of per-task q*-bounds still fits the deadline; since the tasks'
    variations are independent, all tasks land within their q* bounds
    with probability q*^n, so the chain misses with probability at most
    ``1 - q*^n``.  This deliberately ignores tail-composition headroom
    (the bound is conservative) but it is *monotone*: larger DoPs lower
    every bound, raise q*, and lower the predicted miss.
    """
    n = len(nodes)

    def total(q: float) -> float:
        return float(np.sum(model.bound_batch(nodes, q, dops)))

    if total(_Q_HI) <= deadline_s:
        q_star = _Q_HI
    elif total(_Q_LO) > deadline_s:
        return 1.0
    else:
        lo, hi = _Q_LO, _Q_HI
        for _ in range(_Q_ITERS):
            mid = 0.5 * (lo + hi)
            if total(mid) <= deadline_s:
                lo = mid
            else:
                hi = mid
        q_star = lo
    return 1.0 - q_star**n


def predict_miss(model: LatencyModel, wf: Workflow, schedule: Schedule) -> float:
    """Predicted E2E deadline-miss probability of ``schedule``.

    The per-chain analytic bounds (:func:`_chain_miss`) are averaged
    weighted by chain activation rate — a 30 Hz chain contributes three
    times the misses of a 10 Hz chain over any horizon — so the figure
    is comparable to a simulated per-completion violation rate.
    """
    num = 0.0
    den = 0.0
    for chain in wf.chains:
        dops = np.asarray(
            [
                schedule.plans[t].dop if t in schedule.plans else 1
                for t in chain.nodes
            ],
            dtype=np.float64,
        )
        rate = wf.task_rate_hz(chain.nodes[-1])
        num += rate * _chain_miss(model, wf, chain.nodes, dops, chain.deadline_s)
        den += rate
    return num / den if den > 0 else 0.0


# ---------------------------------------------------------------------------
# pruning
# ---------------------------------------------------------------------------
def _chain_feasible(
    model: LatencyModel, wf: Workflow, q: float, tile_cap: int
) -> bool:
    """Cheap necessary condition for a (q, budget) cell: every chain
    must fit its deadline even with each task at its latency-minimal
    DoP candidate under the cap.  Runs entirely on the cached
    ``bound_ladder`` — no compile.  Conservative in the safe direction:
    a cell this check rejects cannot produce a feasible schedule, while
    a cell it accepts may still fail in the compiler (shared-node
    budgets, Phase-III packing)."""
    for chain in wf.chains:
        total = 0.0
        for t in chain.nodes:
            task = wf.tasks[t]
            if task.is_sensor:
                total += model.bound(t, q, 0)
            else:
                cands = task.dop_candidates(tile_cap)
                total += min(model.bound_ladder(t, q, cands))
        if total > chain.deadline_s:
            return False
    return True


# ---------------------------------------------------------------------------
# the search
# ---------------------------------------------------------------------------
_FRONTIER_CACHE: "OrderedDict[tuple, ModeFrontier]" = OrderedDict()
_FRONTIER_CACHE_MAX = 64


def clear_frontier_cache() -> None:
    """Drop memoized mode frontiers (test isolation hook)."""
    _FRONTIER_CACHE.clear()


def _model_fingerprint(model: LatencyModel) -> tuple:
    """Value identity of a latency model: profiles are frozen
    dataclasses and the hardware model is frozen, so equal-valued
    models — e.g. rebuilt per test from the same spec — hash alike."""
    return (tuple(sorted(model.profiles.items())), model.hw)


def _compile_point(
    model: LatencyModel,
    wf: Workflow,
    compiler: GHACompiler,
    q: float,
    n_parts: Optional[int],
    budget: Optional[int],
    dop_prune: Optional[float] = None,
    warm_start: Optional[Dict[str, int]] = None,
) -> FrontierPoint:
    # None means "the compiler's own ceiling" — a caller-configured
    # GHACompiler.tile_budget stays authoritative for full compiles and
    # bounds every budget-swept point from above
    if budget is None:
        budget = compiler.tile_budget
    elif compiler.tile_budget is not None:
        budget = min(budget, compiler.tile_budget)
    sched = dataclasses.replace(
        compiler, q=q, num_partitions=n_parts, tile_budget=budget
    ).compile(model, wf, warm_start=warm_start)
    feasible = (
        not sched.meta["phase1_infeasible"]
        and not sched.meta["phase3_violations"]
    )
    if dop_prune is not None:
        # multi-version compilation set (§IV-D2): the runtime may only
        # resize among DoPs whose binaries this operating point ships
        sched.meta["task_dop_candidates"] = {
            t: model.pruned_candidates(wf.tasks[t], q, dop_prune)
            for t in sched.plans
        }
    return FrontierPoint(
        tiles=sched.peak_tiles,
        miss=predict_miss(model, wf, sched),
        q=q,
        num_partitions=len(sched.partitions),
        budget=sched.meta.get("tile_budget", sched.total_tiles),
        feasible=feasible,
        schedule=sched,
    )


def autotune_mode(
    model: LatencyModel,
    wf: Workflow,
    compiler: Optional[GHACompiler] = None,
    q_grid: Sequence[float] = (0.9, 0.8, 0.7, 0.6, 0.5),
    partition_grid: Optional[Sequence[Optional[int]]] = None,
    budget_fracs: Sequence[float] = (0.85, 0.7),
    stop_at_feasible: bool = False,
    mode_name: str = "",
    dop_prune: Optional[float] = None,
) -> ModeFrontier:
    """Sweep candidate tile budgets for one mode's (model, workflow).

    For every quantile in ``(compiler.q,) + q_grid`` (descending,
    deduplicated) and every partition count in ``partition_grid``
    (default: the compiler's own), a cell passes the bound-ladder
    prune, compiles at the full tile budget, and — when the compile is
    feasible — recompiles at each fraction of its own reserved peak in
    ``budget_fracs``, tracing how far the tile reservation compresses
    before feasibility breaks.  ``stop_at_feasible`` reproduces the
    legacy ladder's early exit (walk q down, stop at the first
    feasible cell) — the cheap path for callers that only want the
    conservative point.  Results are memoized on the *values* of every
    input, so rebuilding an identical stack does not recompile.
    """
    compiler = compiler or GHACompiler()
    if partition_grid is None:
        partition_grid = (compiler.num_partitions,)
    qs = [compiler.q]
    for q in sorted(q_grid, reverse=True):
        if q < compiler.q - 1e-12 and q not in qs:
            qs.append(q)
    grid = tuple(dict.fromkeys(partition_grid))

    cache_key = (
        mode_name,
        _model_fingerprint(model),
        wf.structural_signature,
        (compiler.q, compiler.num_partitions, compiler.phase2_weights,
         compiler.bind_physical, compiler.tile_budget),
        tuple(qs),
        grid,
        tuple(budget_fracs),
        stop_at_feasible,
        dop_prune,
    )
    cached = _FRONTIER_CACHE.get(cache_key)
    if cached is not None:
        _FRONTIER_CACHE.move_to_end(cache_key)
        return cached

    m = model.hw.num_tiles
    if compiler.tile_budget is not None:
        m = max(1, min(m, int(compiler.tile_budget)))
    points: List[FrontierPoint] = []
    seen: set = set()

    def add(p: FrontierPoint) -> None:
        if p.key() not in seen:
            seen.add(p.key())
            points.append(p)

    with metrics.phase("autotune_search"):
        for n_parts in grid:
            found_feasible = False
            compiled_qs: set = set()
            for q in qs:
                if not _chain_feasible(model, wf, q, m):
                    continue
                p = _compile_point(model, wf, compiler, q, n_parts, None, dop_prune)
                compiled_qs.add(q)
                add(p)
                if p.feasible:
                    found_feasible = True
                    # budget-shrunk recompiles of the same (q, n_parts)
                    # cell warm-start Phase II from the full-budget
                    # compile's final partitioning — the task set is
                    # identical and the basin is adjacent, so the
                    # chain-grouped init + greedy merge are skipped.
                    # Full-budget compiles stay cold: they must remain
                    # bitwise equal to the legacy ladder's.
                    warm = {t: pl.partition for t, pl in p.schedule.plans.items()}
                    for frac in budget_fracs:
                        budget = int(math.floor(p.tiles * frac))
                        if budget < len(p.schedule.partitions) or budget >= p.tiles:
                            continue
                        shrunk = _compile_point(
                            model,
                            wf,
                            compiler,
                            q,
                            n_parts,
                            budget,
                            dop_prune,
                            warm_start=warm,
                        )
                        if shrunk.feasible:
                            add(shrunk)
                    if stop_at_feasible:
                        break
            if not found_feasible and qs[-1] not in compiled_qs:
                # ladder fallback: no feasible cell and the lowest quantile
                # was pruned away — compile it anyway so the portfolio has
                # the same (flagged-infeasible) last-rung table to degrade
                # onto that the legacy ladder kept
                add(
                    _compile_point(
                        model, wf, compiler, qs[-1], n_parts, None, dop_prune
                    )
                )

    frontier = ModeFrontier(mode=mode_name, points=points)
    _FRONTIER_CACHE[cache_key] = frontier
    while len(_FRONTIER_CACHE) > _FRONTIER_CACHE_MAX:
        _FRONTIER_CACHE.popitem(last=False)
    return frontier
