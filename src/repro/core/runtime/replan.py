"""Online replanning across driving modes (scenario subsystem runtime).

The offline GHA schedule is compiled against *one* latency model; when
the driving context shifts (urban -> downpour), every per-task budget
and partition capacity in that table is stale.  Recompiling GHA online
is far too slow for a mode switch, so the runtime keeps a *portfolio*
of per-mode schedules precomputed offline (one GHA compile per
registered mode, exactly like multi-version DoP compilation keeps
per-DoP binaries, §IV-D2) and hot-swaps on ``mode_change`` through the
engine's bounded-reallocation path — the swap stalls partitions and
charges migration volume like any other reallocation, so its cost shows
up in ``realloc_frac`` rather than being assumed free.

Any :class:`~repro.core.sim.policy.Policy` can carry an
:class:`OnlineReplanner`: the base class's ``on_mode_change`` delegates
to ``policy.replanner`` when one is attached.

:class:`PredictiveReplanner` goes one step further: instead of paying
the swap exactly *at* the seam — the moment the new mode's load
arrives — it consumes :class:`~repro.core.runtime.forecast.ModeForecast`s
and spends the bounded-realloc window *before* the seam.  A
high-confidence forecast **pre-swaps** the target mode's full table
``lead_s`` ahead of the predicted switch (weight/feature migration is
charged through the same bounded-realloc path, just earlier and under
the old, typically lighter, load); a low-confidence forecast installs a
**blended** table (:func:`blend_schedules`) that hedges per task
between the old and new plans by slack, deferring the capacity move to
the seam itself.  A forecast that never materialises is *reverted*, and
the revert is cheap by construction: PENDING jobs are retargeted, not
migrated, so swapping back charges no checkpoint bytes for work that
never ran under the staged table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, TYPE_CHECKING

from ..gha.compiler import GHACompiler
from ..gha.schedule import Schedule
from ..latency_model import LatencyModel
from ..sim.engine import ForecastStats
from ..workload import Workflow
from .forecast import ModeForecast, ModeForecaster
from .reservation import plan_slack

if TYPE_CHECKING:  # pragma: no cover
    from ..sim.engine import Simulator

__all__ = [
    "SchedulePortfolio", "OnlineReplanner", "PredictiveReplanner",
    "blend_schedules",
]


@dataclasses.dataclass
class SchedulePortfolio:
    """Per-mode precomputed GHA schedules, keyed by mode name."""

    schedules: Dict[str, Schedule]

    def get(self, mode: str) -> Optional[Schedule]:
        return self.schedules.get(mode)

    @classmethod
    def compile(
        cls,
        model: LatencyModel,
        wf: Workflow,
        modes: Mapping[str, object],
        compiler: Optional[GHACompiler] = None,
        q_ladder: tuple = (0.9, 0.8, 0.7, 0.6, 0.5),
    ) -> "SchedulePortfolio":
        """One GHA compile per mode.

        ``modes`` maps mode name to any object exposing
        ``transform_model(model) -> LatencyModel`` (duck-typed so this
        module does not depend on the scenarios package; in practice a
        :class:`repro.scenarios.DrivingMode`).  Modes that also expose
        ``transform_workflow(wf) -> Workflow`` (sensor-rate modulation)
        are compiled against their *own* workflow — and therefore their
        own hyper-period: Phase II's reservation windows, instance
        counts and per-partition capacities all follow the mode's
        sensor rates, so a hot-swap at a rate seam installs a table
        that actually matches the new release pattern.

        Heavy modes may be deadline-infeasible at the compiler's
        conservative quantile: lax budgets then defeat minimum-quota
        control at runtime.  Per the paper's quantile guideline (§V-B:
        relax q under pressure — tail-composition headroom covers the
        difference), each mode steps down ``q_ladder`` until Phases
        I/III report no deadline violations, keeping the most
        conservative *feasible* table per mode.
        """
        compiler = compiler or GHACompiler()
        out: Dict[str, Schedule] = {}
        for name, mode in modes.items():
            m_model = mode.transform_model(model)
            transform_wf = getattr(mode, "transform_workflow", None)
            m_wf = transform_wf(wf) if transform_wf is not None else wf
            for q in (compiler.q,) + tuple(x for x in q_ladder if x < compiler.q):
                sched = dataclasses.replace(compiler, q=q).compile(m_model, m_wf)
                if (
                    not sched.meta["phase1_infeasible"]
                    and not sched.meta["phase3_violations"]
                ):
                    break
            sched.meta["mode"] = name
            sched.meta["hyper_period_s"] = m_wf.hyper_period_s
            # per-task activation periods under this mode's sensor
            # rates: the engine's rate-aware hot-swap re-staggers
            # PENDING ERTs onto the incoming regime's release grid
            # whenever these differ from the outgoing table's
            sched.meta["task_period_s"] = {
                t: 1.0 / m_wf.task_rate_hz(t)
                for t, task in m_wf.tasks.items() if not task.is_sensor
            }
            out[name] = sched
        return cls(out)


def blend_schedules(old: Schedule, new: Schedule, wf: Workflow) -> Schedule:
    """Blend two scheduling tables for a low-confidence transition.

    Partition capacities stay the *old* table's — the expensive part of
    a swap is the capacity move (preempted jobs, checkpoint migration),
    and a transition we are not sure about must not pay it yet.  Plans
    blend **per task by slack** (:func:`~.reservation.plan_slack`):
    each task adopts whichever regime's plan gives it the earlier
    sub-deadline — the more *urgent* of the two targets — so the
    runtime treats every task at least as urgently as either regime
    demands while the context is ambiguous.  DoPs are clamped to the
    retained partition capacities.

    The blend carries the old table's ``task_period_s`` meta: the
    sensor-rate regime has not changed yet, so a later full swap still
    sees the correct outgoing periods and re-staggers at the real seam.
    """
    if len(old.partitions) != len(new.partitions):
        raise ValueError("blend requires schedules with equal partition counts")
    caps = {p.index: p.capacity for p in old.partitions}
    plans = {}
    for task, new_plan in new.plans.items():
        old_plan = old.plans.get(task)
        if old_plan is None:
            pick = new_plan
        else:
            e2e = wf.deadline_offset(task)
            # larger downstream slack == earlier sub-deadline; keep the
            # old plan on ties (fewer retargets)
            pick = (
                new_plan
                if plan_slack(new_plan, e2e) > plan_slack(old_plan, e2e)
                else old_plan
            )
        dop = max(1, min(pick.dop, caps[pick.partition]))
        plans[task] = dataclasses.replace(pick, dop=dop)
    meta: Dict[str, object] = {
        "blend_of": (old.meta.get("mode"), new.meta.get("mode")),
        "hyper_period_s": old.meta.get("hyper_period_s"),
    }
    if old.meta.get("task_period_s") is not None:
        meta["task_period_s"] = old.meta["task_period_s"]
    return Schedule(
        plans=plans,
        partitions=[dataclasses.replace(p) for p in old.partitions],
        q=min(old.q, new.q),
        total_tiles=old.total_tiles,
        meta=meta,
    )


@dataclasses.dataclass
class OnlineReplanner:
    """Reacts to ``mode_change`` by hot-swapping the matching schedule.

    ``resetup`` re-runs ``policy.setup`` after a swap so schedule-derived
    policy state (e.g. ADS-Tile's downstream slack budgets) follows the
    new table.  Modes without a portfolio entry keep the current
    schedule (graceful degradation rather than a hard error — a fleet
    may meet contexts it never compiled for).
    """

    portfolio: SchedulePortfolio
    resetup: bool = True
    #: a real runtime cannot observe "the mode changed" as an event: it
    #: infers the context shift from sensor/latency statistics over a
    #: confirmation window (Liu et al. 2022).  ``detection_delay_s`` > 0
    #: models that window — the reactive swap fires this long *after*
    #: the seam, running the new load on the stale table meanwhile.
    #: The default 0 keeps the original oracle-reactive behaviour.
    detection_delay_s: float = 0.0
    n_swaps: int = 0
    total_stall_s: float = 0.0

    def _swap_to(
        self,
        sim: "Simulator",
        table: Optional[Schedule],
        regime_anchor_s: Optional[float] = None,
        prestage_window_s: float = 0.0,
    ) -> float:
        """Install ``table`` through the bounded-realloc hot-swap path
        (no-op when it is missing or already active)."""
        if table is None or table is sim.schedule:
            return 0.0
        stall = sim.hotswap_schedule(
            table,
            regime_anchor_s=regime_anchor_s,
            prestage_window_s=prestage_window_s,
        )
        self.total_stall_s += stall
        self.n_swaps += 1
        if self.resetup:
            sim.policy.setup(sim)
        return stall

    def _reactive_swap(self, sim: "Simulator", mode: str, now: float) -> None:
        """Swap to ``mode``'s table the way a reactive runtime can:
        immediately with an oracle (delay 0), else after the detection
        confirmation window."""
        if self.detection_delay_s > 0.0:
            sim.arm_forecast(now + self.detection_delay_s, ("detect", mode))
        else:
            self._swap_to(sim, self.portfolio.get(mode))

    def on_mode_change(self, sim: "Simulator", mode: str, now: float) -> None:
        self._reactive_swap(sim, mode, now)

    def on_forecast(self, sim: "Simulator", payload: object, now: float) -> None:
        """Deferred detection: the confirmation window armed at the
        seam has elapsed — swap to the (by now confirmed) mode.  If the
        context shifted again meanwhile, that seam armed its own
        detection event which will re-correct; briefly installing the
        stale detection's table is exactly what a confirmation-window
        runtime does."""
        if (
            isinstance(payload, tuple)
            and len(payload) == 2
            and payload[0] == "detect"
        ):
            self._swap_to(sim, self.portfolio.get(payload[1]))


@dataclasses.dataclass
class PredictiveReplanner(OnlineReplanner):
    """Forecast-driven replanning: pre-swap or blend *ahead* of seams.

    State machine per mode segment:

    1. On entering a mode (run start or ``mode_change``) the replanner
       asks the :class:`~.forecast.ModeForecaster` for the segment's
       end.  A forecast with confidence >= ``confidence_lo`` arms a
       *forecast* scheduling point ``lead_s`` before the predicted
       switch.
    2. When that point fires: confidence >= ``confidence_hi``
       **pre-stages** the target table
       (:meth:`~repro.core.sim.engine.Simulator.prestage_schedule`) —
       its weight/feature deltas background-copy over the remaining
       lead window, charged through the bounded-realloc accounting but
       freezing nothing, while the active table keeps guiding the
       outgoing regime; a confidence in ``[lo, hi)`` installs the
       **blended** table (:func:`blend_schedules` — per-task urgency
       hedge, no capacity move).  A revert guard is armed
       ``revert_grace_s`` past the predicted switch.
    3. At the actual seam the target table is *activated* through the
       ordinary hot-swap: with a correct pre-stage its weights are
       already resident, so the seam stall shrinks to live-state
       preemptions (the part that can never be background-copied)
       instead of the full migration a reactive swap pays at the worst
       moment.  A wrong stage falls back to the reactive swap, having
       wasted only background traffic; a *pre-stage* whose seam never
       comes is reverted for free — the active table was never touched
       — while a blend revert swaps the hedged plans back through the
       ordinary bounded-realloc path (cheap, not free).

    Observed dwells feed back into the forecaster at every seam, and
    repeated reverts inside one segment exponentially damp re-staging
    (``revert_backoff``) so a bad forecaster degrades to reactive
    behaviour instead of thrashing.
    """

    forecaster: Optional[ModeForecaster] = None
    #: stage this many seconds before the predicted switch
    lead_s: float = 0.08
    #: confidence >= hi: full pre-swap; in [lo, hi): blend; < lo: reactive
    confidence_hi: float = 0.6
    confidence_lo: float = 0.25
    #: undo a stage this long after a predicted switch that never came
    revert_grace_s: float = 0.1
    #: per-revert confidence damping within one segment
    revert_backoff: float = 0.5
    #: drain-aware activation: after a correct forecast the staged
    #: table is activated as soon as no partition would have to preempt
    #: a running job (capacity shrinks wait for stragglers of the old
    #: mode to drain), forced at the latest this long past the seam.
    #: 0 activates at the seam unconditionally.
    max_drain_s: float = 0.08
    #: drain-poll interval while waiting for stragglers
    drain_poll_s: float = 0.005
    forecast_stats: ForecastStats = dataclasses.field(
        default_factory=ForecastStats
    )
    _cur_mode: Optional[str] = dataclasses.field(default=None, repr=False)
    _entered_at: float = dataclasses.field(default=0.0, repr=False)
    _staged: Optional[ModeForecast] = dataclasses.field(default=None, repr=False)
    _staged_blend: bool = dataclasses.field(default=False, repr=False)
    _staged_at: float = dataclasses.field(default=0.0, repr=False)
    _segment_reverts: int = dataclasses.field(default=0, repr=False)
    _epoch: int = dataclasses.field(default=0, repr=False)
    #: (mode, seam_s, deadline_s) of a drain-deferred activation
    _pending_act: Optional[tuple] = dataclasses.field(default=None, repr=False)

    # -- engine hooks ----------------------------------------------------
    def on_run_start(self, sim: "Simulator", mode: str, now: float) -> None:
        self._cur_mode = mode
        self._entered_at = now
        self._arm(sim, now)

    def on_mode_change(self, sim: "Simulator", mode: str, now: float) -> None:
        if self._cur_mode is not None and self.forecaster is not None:
            self.forecaster.observe_switch(
                self._cur_mode, mode, now - self._entered_at
            )
        staged = self._staged
        self._epoch += 1          # stale stage/revert/activate events die here
        self._pending_act = None
        stats = self.forecast_stats
        if staged is None:
            self._reactive_swap(sim, mode, now)
        elif staged.target_mode == mode:
            # correct forecast: activate the pre-staged table (its
            # weight deltas are resident) or commit the blend's
            # deferred capacity move.  The forecast told the runtime
            # what to watch for, so the seam is a *confirmation*, not
            # an open-set detection — no detection delay.  Activation
            # is drain-aware: it fires the moment no partition would
            # preempt a straggler of the outgoing mode, bounded by
            # ``max_drain_s``; the swap anchors at the true seam so the
            # rate-aware ERT re-stagger is exact.
            stats.n_hits += 1
            stats.lead_s_total += max(0.0, now - self._staged_at)
            self._activate(sim, mode, now, seam_s=now,
                           deadline_s=now + self.max_drain_s)
        else:
            # wrong forecast: the runtime is watching for the wrong
            # transition and must detect this one like any reactive
            # system — the full confirmation window applies
            stats.n_misses += 1
            self._reactive_swap(sim, mode, now)
        self._staged = None
        self._staged_blend = False
        self._segment_reverts = 0
        self._cur_mode = mode
        self._entered_at = now
        self._arm(sim, now)

    def _reactive_swap(self, sim: "Simulator", mode: str, now: float) -> None:
        # unlike the base replanner — where every seam arms a detect
        # that supersedes the last — a predictive hit activates with no
        # follow-up event, so a stale detect from an earlier missed
        # seam would clobber the correct table and nothing would
        # re-correct it.  Epoch-tag detects so seams kill stale ones.
        if self.detection_delay_s > 0.0:
            sim.arm_forecast(
                now + self.detection_delay_s, ("detect", self._epoch, mode)
            )
        else:
            self._swap_to(sim, self.portfolio.get(mode))

    def on_forecast(self, sim: "Simulator", payload: object, now: float) -> None:
        if not isinstance(payload, tuple) or len(payload) < 2:
            return
        kind = payload[0]
        if kind == "detect":           # deferred miss/fallback detection
            if len(payload) == 3 and payload[1] == self._epoch:
                self._swap_to(sim, self.portfolio.get(payload[2]))
            return
        epoch = payload[1]
        if epoch != self._epoch:
            return
        if kind == "stage":
            self._stage(sim, payload[2], now)
        elif kind == "revert":
            self._revert(sim, now)
        elif kind == "activate":
            if self._pending_act is not None:
                mode, seam_s, deadline_s = self._pending_act
                self._pending_act = None
                self._activate(sim, mode, now, seam_s, deadline_s)

    # -- internals -------------------------------------------------------
    def _arm(self, sim: "Simulator", now: float) -> None:
        if self.forecaster is None or self._cur_mode is None:
            return
        f = self.forecaster.forecast(self._cur_mode, self._entered_at, now)
        if f is None:
            return
        self.forecast_stats.n_forecasts += 1
        conf = f.confidence * (self.revert_backoff ** self._segment_reverts)
        if conf < self.confidence_lo or self.portfolio.get(f.target_mode) is None:
            return
        f = dataclasses.replace(f, confidence=conf)
        sim.arm_forecast(
            max(now, f.switch_at_s - self.lead_s), ("stage", self._epoch, f)
        )

    def _activate(
        self,
        sim: "Simulator",
        mode: str,
        now: float,
        seam_s: float,
        deadline_s: float,
    ) -> None:
        """Drain-aware activation of ``mode``'s table: swap as soon as
        no partition would preempt (every capacity shrink fits under
        the current allocation), forced at ``deadline_s``."""
        table = self.portfolio.get(mode)
        if table is None or table is sim.schedule:
            return
        if now + 1e-12 < deadline_s:
            over = any(
                table.partitions[p.idx].capacity < p.allocated
                for p in sim.parts
            )
            if over:
                self._pending_act = (mode, seam_s, deadline_s)
                sim.arm_forecast(
                    min(now + self.drain_poll_s, deadline_s),
                    ("activate", self._epoch),
                )
                return
        self._swap_to(sim, table, regime_anchor_s=seam_s)

    def _stage(self, sim: "Simulator", f: ModeForecast, now: float) -> None:
        if self._staged is not None:
            return
        new = self.portfolio.get(f.target_mode)
        if new is None or new is sim.schedule:
            return
        stats = self.forecast_stats
        window = max(0.0, f.switch_at_s - now)
        if f.confidence >= self.confidence_hi:
            # full pre-stage: background-copy the target table's
            # weight/feature deltas; the active table — and every
            # running/pending job — is untouched until the seam
            stats.n_preswaps += 1
            stats.prestage_bytes += sim.prestage_schedule(new, window)
            blend = False
        else:
            # low-confidence hedge: install the blended table (plan
            # urgency only, no capacity move); its few adopted-new-plan
            # weight deltas background-copy over the same window
            stats.n_blends += 1
            stats.prestage_stall_s += self._swap_to(
                sim, blend_schedules(sim.schedule, new, sim.wf),
                prestage_window_s=window,
            )
            blend = True
        self._staged = f
        self._staged_blend = blend
        self._staged_at = now
        sim.arm_forecast(
            f.switch_at_s + self.revert_grace_s, ("revert", self._epoch)
        )

    def _revert(self, sim: "Simulator", now: float) -> None:
        if self._staged is None:
            return
        stats = self.forecast_stats
        if self._staged_blend:
            # undo the plan hedge: swap back to the current mode's own
            # table.  No capacity ever moved and PENDING jobs were only
            # retargeted (nothing charged for them), but the tasks the
            # hedge had moved onto new-regime plans pay their weight
            # deltas back through the ordinary bounded-realloc stall —
            # a blend miss is cheap, not free.
            self._swap_to(sim, self.portfolio.get(self._cur_mode))
        # a full pre-stage needs no undo at all: the active table was
        # never touched — the wrong forecast cost exactly the staged
        # background traffic, already charged
        stats.n_misses += 1
        stats.n_reverts += 1
        self._staged = None
        self._staged_blend = False
        self._segment_reverts += 1
        self._arm(sim, now)
