"""Online replanning across driving modes (scenario subsystem runtime).

The offline GHA schedule is compiled against *one* latency model; when
the driving context shifts (urban -> downpour), every per-task budget
and partition capacity in that table is stale.  Recompiling GHA online
is far too slow for a mode switch, so the runtime keeps a *portfolio*
of per-mode schedules precomputed offline (one GHA compile per
registered mode, exactly like multi-version DoP compilation keeps
per-DoP binaries, §IV-D2) and hot-swaps on ``mode_change`` through the
engine's bounded-reallocation path — the swap stalls partitions and
charges migration volume like any other reallocation, so its cost shows
up in ``realloc_frac`` rather than being assumed free.

Any :class:`~repro.core.sim.policy.Policy` can carry an
:class:`OnlineReplanner`: the base class's ``on_mode_change`` delegates
to ``policy.replanner`` when one is attached.

:class:`PredictiveReplanner` goes one step further: instead of paying
the swap exactly *at* the seam — the moment the new mode's load
arrives — it consumes :class:`~repro.core.runtime.forecast.ModeForecast`s
and spends the bounded-realloc window *before* the seam.  A
high-confidence forecast **pre-swaps** the target mode's full table
``lead_s`` ahead of the predicted switch (weight/feature migration is
charged through the same bounded-realloc path, just earlier and under
the old, typically lighter, load); a low-confidence forecast installs a
**blended** table (:func:`blend_schedules`) that hedges per task
between the old and new plans by slack, deferring the capacity move to
the seam itself.  A forecast that never materialises is *reverted*, and
the revert is cheap by construction: PENDING jobs are retargeted, not
migrated, so swapping back charges no checkpoint bytes for work that
never ran under the staged table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, TYPE_CHECKING

from ...obs import metrics
from ..gha.compiler import GHACompiler
from ..gha.schedule import Schedule
from ..latency_model import LatencyModel
from ..sim.engine import ForecastStats
from ..workload import Workflow
from .autotune import FrontierPoint, ModeFrontier, autotune_mode
from .forecast import ModeForecast, ModeForecaster
from .reservation import most_urgent_plan

if TYPE_CHECKING:  # pragma: no cover
    from ..sim.engine import Simulator

__all__ = [
    "SchedulePortfolio", "OnlineReplanner", "PredictiveReplanner",
    "blend_schedules",
]


@dataclasses.dataclass
class SchedulePortfolio:
    """Per-mode precomputed GHA schedules, keyed by mode name.

    ``frontiers`` keeps each mode's full autotuner search
    (:class:`~.autotune.ModeFrontier`) and ``selected`` the operating
    point actually installed — the predictive replanner's blend tables
    draw alternative per-task plans from them (transition hedging
    co-optimizes the quantile with the plan, see :func:`blend_schedules`).
    """

    schedules: Dict[str, Schedule]
    frontiers: Dict[str, ModeFrontier] = dataclasses.field(default_factory=dict)
    selected: Dict[str, FrontierPoint] = dataclasses.field(default_factory=dict)

    def get(self, mode: str) -> Optional[Schedule]:
        return self.schedules.get(mode)

    def blend_alternative(
        self, mode: str, num_partitions: int
    ) -> Optional[Schedule]:
        """A more conservative same-partition-count frontier table for
        ``mode``, if the autotuner kept one beyond the installed point
        (None otherwise).  Transition blends hedge per task against it."""
        frontier = self.frontiers.get(mode)
        point = self.selected.get(mode)
        if frontier is None or point is None:
            return None
        alt = frontier.blend_source(num_partitions, point)
        return None if alt is None else alt.schedule

    @classmethod
    def compile(
        cls,
        model: LatencyModel,
        wf: Workflow,
        modes: Mapping[str, object],
        compiler: Optional[GHACompiler] = None,
        q_ladder: tuple = (0.9, 0.8, 0.7, 0.6, 0.5),
        target_miss: Optional[float] = None,
        partition_span: int = 1,
        budget_fracs: tuple = (0.85, 0.7),
        dop_prune: Optional[float] = None,
        harmonize_partitions: bool = True,
    ) -> "SchedulePortfolio":
        """Per-mode tile-budget autotuning (see :mod:`~.autotune`).

        ``modes`` maps mode name to any object exposing
        ``transform_model(model) -> LatencyModel`` (duck-typed so this
        module does not depend on the scenarios package; in practice a
        :class:`repro.scenarios.DrivingMode`).  Modes that also expose
        ``transform_workflow(wf) -> Workflow`` (sensor-rate modulation)
        are compiled against their *own* workflow — and therefore their
        own hyper-period: Phase II's reservation windows, instance
        counts and per-partition capacities all follow the mode's
        sensor rates, so a hot-swap at a rate seam installs a table
        that actually matches the new release pattern.

        With no ``target_miss`` each mode keeps the most conservative
        deadline-feasible operating point — the walk down ``q_ladder``
        stops at the first feasible quantile, exactly the legacy
        q-relaxation behaviour (§V-B: relax q under pressure,
        tail-composition headroom covers the difference).

        With a ``target_miss``, the full joint search runs: quantiles
        × partition counts (``compiler.num_partitions ±
        partition_span``) × tile budgets (``budget_fracs`` of each
        feasible compile's own peak), and every mode installs the
        *cheapest* frontier point whose predicted E2E miss probability
        meets the target.

        ``harmonize_partitions`` (the legacy default) restricts the
        spatial axis to one common partition count across modes — the
        one minimizing the portfolio's total reserved tiles subject to
        every mode meeting the target.  This predates the engine's
        online partition morphing, which lets a hot-swap split/merge
        partitions at the seam; pass ``False`` to let every mode keep
        its *own* best partition count (morph stalls are charged
        through the same bounded-realloc path as any other swap).
        """
        with metrics.phase("portfolio_compile"):
            compiler = compiler or GHACompiler()
            explore = target_miss is not None
            base_p = compiler.num_partitions
            frontiers: Dict[str, ModeFrontier] = {}
            mode_wfs: Dict[str, Workflow] = {}
            for name, mode in modes.items():
                m_model = mode.transform_model(model)
                transform_wf = getattr(mode, "transform_workflow", None)
                m_wf = transform_wf(wf) if transform_wf is not None else wf
                if explore and base_p is not None and base_p > 1:
                    n_dnn = len(m_wf.dnn_tasks)
                    grid = tuple(dict.fromkeys(
                        max(2, min(p, n_dnn))
                        for p in range(base_p - partition_span,
                                       base_p + partition_span + 1)
                    ))
                else:
                    grid = (base_p,)
                frontiers[name] = autotune_mode(
                    m_model, m_wf, compiler,
                    q_grid=tuple(q_ladder),
                    partition_grid=grid,
                    budget_fracs=tuple(budget_fracs) if explore else (),
                    stop_at_feasible=not explore,
                    mode_name=name,
                    dop_prune=dop_prune,
                )
                mode_wfs[name] = m_wf

            # joint spatial harmonization (legacy): pin every mode to
            # one partition count.  With morphing (harmonize off) each
            # mode selects freely and the engine splits/merges online.
            p_star: Optional[int] = None
            if explore and harmonize_partitions:
                common = set.intersection(
                    *(set(f.partition_counts()) for f in frontiers.values())
                )
                if common:
                    def p_score(p: int) -> tuple:
                        sels = [f.select(target_miss, p) for f in frontiers.values()]
                        short = sum(
                            (not s.feasible) or s.miss > target_miss for s in sels
                        )
                        tiles = sum(s.tiles for s in sels)
                        anchor = abs(p - base_p) if base_p is not None else 0
                        return (short, tiles, anchor, p)
                    p_star = min(sorted(common), key=p_score)

            out: Dict[str, Schedule] = {}
            selected: Dict[str, FrontierPoint] = {}
            for name, frontier in frontiers.items():
                point = frontier.select(target_miss, p_star)
                m_wf = mode_wfs[name]
                sched = point.schedule
                sched.meta["mode"] = name
                sched.meta["hyper_period_s"] = m_wf.hyper_period_s
                # per-task activation periods under this mode's sensor
                # rates: the engine's rate-aware hot-swap re-staggers
                # PENDING ERTs onto the incoming regime's release grid
                # whenever these differ from the outgoing table's
                sched.meta["task_period_s"] = {
                    t: 1.0 / m_wf.task_rate_hz(t)
                    for t, task in m_wf.tasks.items() if not task.is_sensor
                }
                sched.meta["autotune"] = frontier.meta(point)
                out[name] = sched
                selected[name] = point
            return cls(out, frontiers=frontiers, selected=selected)


def blend_schedules(
    old: Schedule,
    new: Schedule,
    wf: Workflow,
    alt: Optional[Schedule] = None,
) -> Schedule:
    """Blend two scheduling tables for a low-confidence transition.

    Partition capacities stay the *old* table's — the expensive part of
    a swap is the capacity move (preempted jobs, checkpoint migration),
    and a transition we are not sure about must not pay it yet.  Plans
    blend **per task by slack**
    (:func:`~.reservation.most_urgent_plan`): each task adopts
    whichever regime's plan gives it the earlier sub-deadline — the
    more *urgent* of the targets — so the runtime treats every task at
    least as urgently as either regime demands while the context is
    ambiguous.  DoPs are clamped to the retained partition capacities.

    ``alt`` optionally adds a third per-task candidate: a more
    conservative frontier table of the target mode
    (:meth:`SchedulePortfolio.blend_alternative`).  A budget-tightened
    portfolio installs relaxed-quantile plans, but while the context is
    *ambiguous* the hedge may draw the high-quantile plan instead —
    the blend co-optimizes the quantile with the plan per task.

    The blend carries the old table's ``task_period_s`` meta: the
    sensor-rate regime has not changed yet, so a later full swap still
    sees the correct outgoing periods and re-staggers at the real seam.
    """
    if len(old.partitions) != len(new.partitions):
        raise ValueError("blend requires schedules with equal partition counts")
    if alt is not None and len(alt.partitions) != len(old.partitions):
        raise ValueError("blend alternative must match the partition count")
    caps = {p.index: p.capacity for p in old.partitions}
    plans = {}
    for task, new_plan in new.plans.items():
        # candidate order matters: earlier entries win slack ties, so
        # the old plan (fewest retargets) dominates, then the target
        # mode's installed plan, then the conservative alternative
        cands = [new_plan]
        old_plan = old.plans.get(task)
        if old_plan is not None:
            cands.insert(0, old_plan)
        if alt is not None and task in alt.plans:
            cands.append(alt.plans[task])
        pick = most_urgent_plan(cands, wf.deadline_offset(task))
        dop = max(1, min(pick.dop, caps[pick.partition]))
        plans[task] = dataclasses.replace(pick, dop=dop)
    meta: Dict[str, object] = {
        "blend_of": (old.meta.get("mode"), new.meta.get("mode")),
        "hyper_period_s": old.meta.get("hyper_period_s"),
    }
    if old.meta.get("task_period_s") is not None:
        meta["task_period_s"] = old.meta["task_period_s"]
    # multi-version DoP sets (§IV-D2): during a transition both
    # regimes' compiled versions are resident (the new table's were
    # pre-staged), so the blend's runtime ladder is the per-task union
    # — never the full workflow ladder, which would let FitQuota pick
    # versions neither table compiled
    cand_metas = [
        s.meta.get("task_dop_candidates")
        for s in ((old, new) + ((alt,) if alt is not None else ()))
    ]
    if any(c is not None for c in cand_metas):
        merged: Dict[str, tuple] = {}
        for task in plans:
            sets = [set(c[task]) for c in cand_metas if c and task in c]
            if sets:
                merged[task] = tuple(sorted(set.union(*sets)))
        meta["task_dop_candidates"] = merged
    return Schedule(
        plans=plans,
        partitions=[dataclasses.replace(p) for p in old.partitions],
        q=min(old.q, new.q),
        total_tiles=old.total_tiles,
        meta=meta,
    )


@dataclasses.dataclass
class OnlineReplanner:
    """Reacts to ``mode_change`` by hot-swapping the matching schedule.

    ``resetup`` re-runs ``policy.setup`` after a swap so schedule-derived
    policy state (e.g. ADS-Tile's downstream slack budgets) follows the
    new table.  Modes without a portfolio entry keep the current
    schedule (graceful degradation rather than a hard error — a fleet
    may meet contexts it never compiled for).
    """

    portfolio: SchedulePortfolio
    resetup: bool = True
    #: a real runtime cannot observe "the mode changed" as an event: it
    #: infers the context shift from sensor/latency statistics over a
    #: confirmation window (Liu et al. 2022).  ``detection_delay_s`` > 0
    #: models that window — the reactive swap fires this long *after*
    #: the seam, running the new load on the stale table meanwhile.
    #: The default 0 keeps the original oracle-reactive behaviour.
    detection_delay_s: float = 0.0
    n_swaps: int = 0
    total_stall_s: float = 0.0
    #: degraded-operation response (docs/degradation.md): on a tile
    #: fault the replanner drops to the cheapest frontier point that
    #: fits the surviving tiles (the L2P re-placement then maps the new
    #: table around the dead tiles); on recovery it restores the mode's
    #: own table.  Off, the policy rides the fault out on its shrunken
    #: partition.
    respond_to_faults: bool = True
    n_degrade_swaps: int = 0
    _fault_depth: int = dataclasses.field(default=0, repr=False)
    _fault_swapped: bool = dataclasses.field(default=False, repr=False)

    def _swap_to(
        self,
        sim: "Simulator",
        table: Optional[Schedule],
        regime_anchor_s: Optional[float] = None,
        prestage_window_s: float = 0.0,
    ) -> float:
        """Install ``table`` through the bounded-realloc hot-swap path
        (no-op when it is missing or already active)."""
        if table is None or table is sim.schedule:
            return 0.0
        stall = sim.hotswap_schedule(
            table,
            regime_anchor_s=regime_anchor_s,
            prestage_window_s=prestage_window_s,
        )
        self.total_stall_s += stall
        self.n_swaps += 1
        if self.resetup:
            sim.policy.setup(sim)
        return stall

    def _reactive_swap(self, sim: "Simulator", mode: str, now: float) -> None:
        """Swap to ``mode``'s table the way a reactive runtime can:
        immediately with an oracle (delay 0), else after the detection
        confirmation window.  The seam time (``now``) rides in the
        detect payload: the regime's sensor timers re-anchored at the
        *seam*, so the deferred swap must re-stagger straddling ERTs
        onto that grid — anchoring at the detection instant would admit
        them mid-frame, the exact failure the rate-aware re-stagger
        exists to prevent."""
        if self.detection_delay_s > 0.0:
            sim.arm_forecast(
                now + self.detection_delay_s, ("detect", mode, now)
            )
        else:
            self._swap_to(sim, self.portfolio.get(mode))

    def on_mode_change(self, sim: "Simulator", mode: str, now: float) -> None:
        self._reactive_swap(sim, mode, now)

    def on_degrade(self, sim: "Simulator", event: object, begin: bool) -> None:
        """Tile-fault response: re-plan against the reduced tile budget.

        On fault onset the engine has already shrunk (and possibly
        evacuated) the struck partition; this hook then swaps to the
        mode frontier's best operating point that *fits the surviving
        tiles* (:meth:`~.autotune.ModeFrontier.select_within_tiles`) —
        installing it lets the L2P indirection re-place the table
        around the dead tiles, so the new table runs at full nominal
        capacity.  If the installed table already fits, it is
        re-installed (a copy, forcing the re-placement swap).  When the
        last fault lifts, the mode's own table is restored.  Other
        degradation kinds need no spatial response: throttles and
        bandwidth loss are temporal, dropout storms act through the
        trace.
        """
        if not self.respond_to_faults or getattr(event, "kind", "") != "tile_fault":
            return
        mode = sim._mode_now
        if begin:
            self._fault_depth += 1
            avail = sim.hw.num_tiles - sim.fault_tiles_lost
            frontier = self.portfolio.frontiers.get(mode) if mode else None
            table = None
            if frontier is not None:
                point = frontier.select_within_tiles(avail)
                table = None if point is None else point.schedule
            if table is None:
                table = self.portfolio.get(mode)
                if table is not None and table.peak_tiles > avail:
                    table = None  # nothing fits: ride the fault out
            if table is None:
                return
            if table is sim.schedule:
                # same table, new placement: force the swap so the L2P
                # remap (and its honest stall) actually happens
                table = dataclasses.replace(table)
            self._swap_to(sim, table)
            self.n_degrade_swaps += 1
            self._fault_swapped = True
        else:
            self._fault_depth = max(0, self._fault_depth - 1)
            if self._fault_depth == 0 and self._fault_swapped:
                self._fault_swapped = False
                self._swap_to(sim, self.portfolio.get(mode))

    def on_forecast(self, sim: "Simulator", payload: object, now: float) -> None:
        """Deferred detection: the confirmation window armed at the
        seam has elapsed — swap to the (by now confirmed) mode,
        anchored at the seam recorded in the payload.  If the context
        shifted again meanwhile, that seam armed its own detection
        event which will re-correct; briefly installing the stale
        detection's table is exactly what a confirmation-window
        runtime does."""
        if (
            isinstance(payload, tuple)
            and len(payload) == 3
            and payload[0] == "detect"
        ):
            self._swap_to(
                sim, self.portfolio.get(payload[1]),
                regime_anchor_s=payload[2],
            )


@dataclasses.dataclass
class PredictiveReplanner(OnlineReplanner):
    """Forecast-driven replanning: pre-swap or blend *ahead* of seams.

    State machine per mode segment:

    1. On entering a mode (run start or ``mode_change``) the replanner
       asks the :class:`~.forecast.ModeForecaster` for the segment's
       end.  A forecast with confidence >= ``confidence_lo`` arms a
       *forecast* scheduling point ``lead_s`` before the predicted
       switch.
    2. When that point fires: confidence >= ``confidence_hi``
       **pre-stages** the target table
       (:meth:`~repro.core.sim.engine.Simulator.prestage_schedule`) —
       its weight/feature deltas background-copy over the remaining
       lead window, charged through the bounded-realloc accounting but
       freezing nothing, while the active table keeps guiding the
       outgoing regime; a confidence in ``[lo, hi)`` installs the
       **blended** table (:func:`blend_schedules` — per-task urgency
       hedge, no capacity move).  A revert guard is armed
       ``revert_grace_s`` past the predicted switch.
    3. At the actual seam the target table is *activated* through the
       ordinary hot-swap: with a correct pre-stage its weights are
       already resident, so the seam stall shrinks to live-state
       preemptions (the part that can never be background-copied)
       instead of the full migration a reactive swap pays at the worst
       moment.  A wrong stage falls back to the reactive swap, having
       wasted only background traffic; a *pre-stage* whose seam never
       comes is reverted for free — the active table was never touched
       — while a blend revert swaps the hedged plans back through the
       ordinary bounded-realloc path (cheap, not free).

    Observed dwells feed back into the forecaster at every seam, and
    repeated reverts inside one segment exponentially damp re-staging
    (``revert_backoff``) so a bad forecaster degrades to reactive
    behaviour instead of thrashing.
    """

    forecaster: Optional[ModeForecaster] = None
    #: stage this many seconds before the predicted switch
    lead_s: float = 0.08
    #: confidence >= hi: full pre-swap; in [lo, hi): blend; < lo: reactive
    confidence_hi: float = 0.6
    confidence_lo: float = 0.25
    #: undo a stage this long after a predicted switch that never came
    revert_grace_s: float = 0.1
    #: per-revert confidence damping within one segment
    revert_backoff: float = 0.5
    #: drain-aware activation: after a correct forecast the staged
    #: table is activated as soon as no partition would have to preempt
    #: a running job (capacity shrinks wait for stragglers of the old
    #: mode to drain), forced at the latest this long past the seam.
    #: 0 activates at the seam unconditionally.  While waiting, the
    #: engine's drain watch re-checks at every partition ``finish``
    #: event — allocation only ever drops when a job finishes, so the
    #: swap lands at the exact drain instant instead of on a poll grid.
    max_drain_s: float = 0.08
    forecast_stats: ForecastStats = dataclasses.field(
        default_factory=ForecastStats
    )
    _cur_mode: Optional[str] = dataclasses.field(default=None, repr=False)
    _entered_at: float = dataclasses.field(default=0.0, repr=False)
    _staged: Optional[ModeForecast] = dataclasses.field(default=None, repr=False)
    _staged_blend: bool = dataclasses.field(default=False, repr=False)
    _staged_at: float = dataclasses.field(default=0.0, repr=False)
    _segment_reverts: int = dataclasses.field(default=0, repr=False)
    _epoch: int = dataclasses.field(default=0, repr=False)
    #: (mode, seam_s, deadline_s) of a drain-deferred activation
    _pending_act: Optional[tuple] = dataclasses.field(default=None, repr=False)

    # -- engine hooks ----------------------------------------------------
    def on_run_start(self, sim: "Simulator", mode: str, now: float) -> None:
        self._cur_mode = mode
        self._entered_at = now
        self._arm(sim, now)

    def on_mode_change(self, sim: "Simulator", mode: str, now: float) -> None:
        if self._cur_mode is not None and self.forecaster is not None:
            self.forecaster.observe_switch(
                self._cur_mode, mode, now - self._entered_at
            )
        staged = self._staged
        self._epoch += 1          # stale stage/revert/activate events die here
        if self._pending_act is not None:
            self._pending_act = None
            sim.clear_drain_watch()
        stats = self.forecast_stats
        if staged is None:
            self._reactive_swap(sim, mode, now)
        elif staged.target_mode == mode:
            # correct forecast: activate the pre-staged table (its
            # weight deltas are resident) or commit the blend's
            # deferred capacity move.  The forecast told the runtime
            # what to watch for, so the seam is a *confirmation*, not
            # an open-set detection — no detection delay.  Activation
            # is drain-aware: it fires the moment no partition would
            # preempt a straggler of the outgoing mode, bounded by
            # ``max_drain_s``; the swap anchors at the true seam so the
            # rate-aware ERT re-stagger is exact.
            stats.n_hits += 1
            stats.lead_s_total += max(0.0, now - self._staged_at)
            self._activate(sim, mode, now, seam_s=now,
                           deadline_s=now + self.max_drain_s)
        else:
            # wrong forecast: the runtime is watching for the wrong
            # transition and must detect this one like any reactive
            # system — the full confirmation window applies
            stats.n_misses += 1
            self._reactive_swap(sim, mode, now)
        self._staged = None
        self._staged_blend = False
        self._segment_reverts = 0
        self._cur_mode = mode
        self._entered_at = now
        self._arm(sim, now)

    def _reactive_swap(self, sim: "Simulator", mode: str, now: float) -> None:
        # unlike the base replanner — where every seam arms a detect
        # that supersedes the last — a predictive hit activates with no
        # follow-up event, so a stale detect from an earlier missed
        # seam would clobber the correct table and nothing would
        # re-correct it.  Epoch-tag detects so seams kill stale ones.
        # The seam time rides along as the regime anchor (see the base
        # class's _reactive_swap).
        if self.detection_delay_s > 0.0:
            sim.arm_forecast(
                now + self.detection_delay_s,
                ("detect", self._epoch, mode, now),
            )
        else:
            self._swap_to(sim, self.portfolio.get(mode))

    def on_forecast(self, sim: "Simulator", payload: object, now: float) -> None:
        if not isinstance(payload, tuple) or len(payload) < 2:
            return
        kind = payload[0]
        if kind == "detect":           # deferred miss/fallback detection
            if len(payload) == 4 and payload[1] == self._epoch:
                self._swap_to(
                    sim, self.portfolio.get(payload[2]),
                    regime_anchor_s=payload[3],
                )
            return
        epoch = payload[1]
        if epoch != self._epoch:
            return
        if kind == "stage":
            self._stage(sim, payload[2], now)
        elif kind == "revert":
            self._revert(sim, now)
        elif kind in ("activate", "drain"):
            # "drain": the engine's drain watch saw a partition free
            # allocation (a finish event) while an activation was
            # deferred; "activate": the max_drain_s force deadline
            if self._pending_act is not None:
                mode, seam_s, deadline_s = self._pending_act
                self._activate(sim, mode, now, seam_s, deadline_s)

    # -- internals -------------------------------------------------------
    def _arm(self, sim: "Simulator", now: float) -> None:
        if self.forecaster is None or self._cur_mode is None:
            return
        f = self.forecaster.forecast(self._cur_mode, self._entered_at, now)
        if f is None:
            return
        self.forecast_stats.n_forecasts += 1
        conf = f.confidence * (self.revert_backoff ** self._segment_reverts)
        if conf < self.confidence_lo or self.portfolio.get(f.target_mode) is None:
            return
        f = dataclasses.replace(f, confidence=conf)
        sim.arm_forecast(
            max(now, f.switch_at_s - self.lead_s), ("stage", self._epoch, f)
        )

    def _activate(
        self,
        sim: "Simulator",
        mode: str,
        now: float,
        seam_s: float,
        deadline_s: float,
    ) -> None:
        """Drain-aware activation of ``mode``'s table: swap as soon as
        no partition would preempt (every capacity shrink fits under
        the current allocation), forced at ``deadline_s``.

        While stragglers hold the over-capacity tiles the replanner
        arms the engine's *drain watch*: allocation can only drop at a
        job ``finish``, so the watch re-fires this check at exactly
        those instants and the swap lands at the true drain point.  A
        single ``activate`` forecast event at ``deadline_s`` bounds the
        wait (stragglers of a dying mode must not block the new table
        forever)."""
        table = self.portfolio.get(mode)
        if table is None or table is sim.schedule:
            self._pending_act = None
            sim.clear_drain_watch()
            return
        if now + 1e-12 < deadline_s:
            n_new = len(table.partitions)
            over = any(
                # partitions the swap would morph away must drain too
                (p.allocated > 0 if p.idx >= n_new
                 else table.partitions[p.idx].capacity < p.allocated)
                for p in sim.parts
            )
            if over:
                if self._pending_act is None:
                    # first deferral: arm the force deadline once; the
                    # per-finish re-checks ride the drain watch
                    sim.arm_forecast(deadline_s, ("activate", self._epoch))
                self._pending_act = (mode, seam_s, deadline_s)
                sim.arm_drain_watch(("drain", self._epoch))
                return
        self._pending_act = None
        sim.clear_drain_watch()
        self._swap_to(sim, table, regime_anchor_s=seam_s)

    def _stage(self, sim: "Simulator", f: ModeForecast, now: float) -> None:
        if self._staged is not None:
            return
        new = self.portfolio.get(f.target_mode)
        if new is None or new is sim.schedule:
            return
        stats = self.forecast_stats
        window = max(0.0, f.switch_at_s - now)
        morphing = len(new.partitions) != len(sim.schedule.partitions)
        if f.confidence >= self.confidence_hi or morphing:
            # a blend keeps the old partitions by construction, so a
            # cross-partition-count transition (unharmonized portfolio)
            # hedges by pre-staging instead
            # full pre-stage: background-copy the target table's
            # weight/feature deltas; the active table — and every
            # running/pending job — is untouched until the seam
            stats.n_preswaps += 1
            stats.prestage_bytes += sim.prestage_schedule(new, window)
            blend = False
        else:
            # low-confidence hedge: install the blended table (plan
            # urgency only, no capacity move); its few adopted-new-plan
            # weight deltas background-copy over the same window.  The
            # hedge draws a third per-task candidate from the target
            # mode's frontier (the most conservative feasible table at
            # this partition count) so a budget-tightened portfolio
            # still hedges with the high-quantile plan while the
            # context is ambiguous.
            stats.n_blends += 1
            alt = self.portfolio.blend_alternative(
                f.target_mode, len(sim.schedule.partitions)
            )
            stats.prestage_stall_s += self._swap_to(
                sim, blend_schedules(sim.schedule, new, sim.wf, alt=alt),
                prestage_window_s=window,
            )
            blend = True
        self._staged = f
        self._staged_blend = blend
        self._staged_at = now
        sim.arm_forecast(
            f.switch_at_s + self.revert_grace_s, ("revert", self._epoch)
        )

    def _revert(self, sim: "Simulator", now: float) -> None:
        if self._staged is None:
            return
        stats = self.forecast_stats
        if self._staged_blend:
            # undo the plan hedge: swap back to the current mode's own
            # table.  No capacity ever moved and PENDING jobs were only
            # retargeted (nothing charged for them), but the tasks the
            # hedge had moved onto new-regime plans pay their weight
            # deltas back through the ordinary bounded-realloc stall —
            # a blend miss is cheap, not free.
            self._swap_to(sim, self.portfolio.get(self._cur_mode))
        # a full pre-stage needs no undo at all: the active table was
        # never touched — the wrong forecast cost exactly the staged
        # background traffic, already charged
        stats.n_misses += 1
        stats.n_reverts += 1
        self._staged = None
        self._staged_blend = False
        self._segment_reverts += 1
        self._arm(sim, now)
