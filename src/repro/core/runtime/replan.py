"""Online replanning across driving modes (scenario subsystem runtime).

The offline GHA schedule is compiled against *one* latency model; when
the driving context shifts (urban -> downpour), every per-task budget
and partition capacity in that table is stale.  Recompiling GHA online
is far too slow for a mode switch, so the runtime keeps a *portfolio*
of per-mode schedules precomputed offline (one GHA compile per
registered mode, exactly like multi-version DoP compilation keeps
per-DoP binaries, §IV-D2) and hot-swaps on ``mode_change`` through the
engine's bounded-reallocation path — the swap stalls partitions and
charges migration volume like any other reallocation, so its cost shows
up in ``realloc_frac`` rather than being assumed free.

Any :class:`~repro.core.sim.policy.Policy` can carry an
:class:`OnlineReplanner`: the base class's ``on_mode_change`` delegates
to ``policy.replanner`` when one is attached.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, TYPE_CHECKING

from ..gha.compiler import GHACompiler
from ..gha.schedule import Schedule
from ..latency_model import LatencyModel
from ..workload import Workflow

if TYPE_CHECKING:  # pragma: no cover
    from ..sim.engine import Simulator

__all__ = ["SchedulePortfolio", "OnlineReplanner"]


@dataclasses.dataclass
class SchedulePortfolio:
    """Per-mode precomputed GHA schedules, keyed by mode name."""

    schedules: Dict[str, Schedule]

    def get(self, mode: str) -> Optional[Schedule]:
        return self.schedules.get(mode)

    @classmethod
    def compile(
        cls,
        model: LatencyModel,
        wf: Workflow,
        modes: Mapping[str, object],
        compiler: Optional[GHACompiler] = None,
        q_ladder: tuple = (0.9, 0.8, 0.7, 0.6, 0.5),
    ) -> "SchedulePortfolio":
        """One GHA compile per mode.

        ``modes`` maps mode name to any object exposing
        ``transform_model(model) -> LatencyModel`` (duck-typed so this
        module does not depend on the scenarios package; in practice a
        :class:`repro.scenarios.DrivingMode`).  Modes that also expose
        ``transform_workflow(wf) -> Workflow`` (sensor-rate modulation)
        are compiled against their *own* workflow — and therefore their
        own hyper-period: Phase II's reservation windows, instance
        counts and per-partition capacities all follow the mode's
        sensor rates, so a hot-swap at a rate seam installs a table
        that actually matches the new release pattern.

        Heavy modes may be deadline-infeasible at the compiler's
        conservative quantile: lax budgets then defeat minimum-quota
        control at runtime.  Per the paper's quantile guideline (§V-B:
        relax q under pressure — tail-composition headroom covers the
        difference), each mode steps down ``q_ladder`` until Phases
        I/III report no deadline violations, keeping the most
        conservative *feasible* table per mode.
        """
        compiler = compiler or GHACompiler()
        out: Dict[str, Schedule] = {}
        for name, mode in modes.items():
            m_model = mode.transform_model(model)
            transform_wf = getattr(mode, "transform_workflow", None)
            m_wf = transform_wf(wf) if transform_wf is not None else wf
            for q in (compiler.q,) + tuple(x for x in q_ladder if x < compiler.q):
                sched = dataclasses.replace(compiler, q=q).compile(m_model, m_wf)
                if (
                    not sched.meta["phase1_infeasible"]
                    and not sched.meta["phase3_violations"]
                ):
                    break
            sched.meta["mode"] = name
            sched.meta["hyper_period_s"] = m_wf.hyper_period_s
            out[name] = sched
        return cls(out)


@dataclasses.dataclass
class OnlineReplanner:
    """Reacts to ``mode_change`` by hot-swapping the matching schedule.

    ``resetup`` re-runs ``policy.setup`` after a swap so schedule-derived
    policy state (e.g. ADS-Tile's downstream slack budgets) follows the
    new table.  Modes without a portfolio entry keep the current
    schedule (graceful degradation rather than a hard error — a fleet
    may meet contexts it never compiled for).
    """

    portfolio: SchedulePortfolio
    resetup: bool = True
    n_swaps: int = 0
    total_stall_s: float = 0.0

    def on_mode_change(self, sim: "Simulator", mode: str, now: float) -> None:
        new = self.portfolio.get(mode)
        if new is None or new is sim.schedule:
            return
        self.total_stall_s += sim.hotswap_schedule(new)
        self.n_swaps += 1
        if self.resetup:
            sim.policy.setup(sim)
