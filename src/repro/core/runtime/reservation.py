"""Elastic reservation primitives (paper §IV-B2).

* **Admission control** — a task is not eligible for colocation until
  its Earliest-Ready-Time (ERT, ``t_v``); the engine's
  ``eligible_jobs(admitted_only=True)`` implements the filter.
* **Quota control** — ``fit_quota`` selects the *minimum* tile quota
  expected to finish a job before its target, leaving residual tiles
  idle for future urgent arrivals instead of distributing all spare
  tiles (the anti-work-conserving choice that trades a little present
  utilisation for lower future timeout risk).
"""
from __future__ import annotations

from typing import Sequence

from ..sim.engine import Job

__all__ = ["fit_quota", "plan_slack", "most_urgent_plan"]


def plan_slack(plan, e2e_offset_s: float) -> float:
    """Downstream slack a scheduling-table entry leaves a task: the gap
    between its sub-deadline and the tightest E2E deadline offset
    through it (``Workflow.deadline_offset``).  A more demanding regime
    schedules the task to an *earlier* sub-deadline and therefore
    leaves a **larger** slack value — which is why
    :func:`most_urgent_plan` (and schedule blending on top of it) picks
    the maximum."""
    return e2e_offset_s - plan.subdeadline_s


def most_urgent_plan(plans: Sequence, e2e_offset_s: float):
    """The candidate plan with the largest downstream slack — i.e. the
    earliest sub-deadline, the most *urgent* target among the regimes
    on offer.  Earlier candidates win ties, so callers order the list
    by retarget cost (current plan first).  Schedule blending picks
    each task's transition-hedge plan with this."""
    best = plans[0]
    best_slack = plan_slack(best, e2e_offset_s)
    for p in plans[1:]:
        s = plan_slack(p, e2e_offset_s)
        if s > best_slack:
            best, best_slack = p, s
    return best


def fit_quota(
    job: Job,
    candidates: Sequence[int],
    target_t: float,
    now: float,
    tile_flops: float,
    cap: int,
) -> int:
    """FitQuota (Alg. 2 line 11): smallest DoP candidate <= ``cap`` whose
    predicted finish meets ``target_t``; if none meets it, the largest
    candidate that fits ``cap`` (best effort); 0 if nothing fits."""
    slack = target_t - now
    rem = 1.0 - job.progress
    durs = job.duration_ladder(tuple(candidates), tile_flops)
    pick = 0
    for c, d in zip(candidates, durs):
        if c > cap:
            break
        pick = c
        if rem * d <= slack:
            return c
    return pick
