"""Driving-context switch forecasting (predictive replanning, stage 1).

The reactive replanner pays the stop-migrate-restart swap exactly *at*
the mode boundary — the moment the new mode's load arrives, i.e. the
worst possible time.  But context switches in an ADS are predictable
seconds ahead: the route planner knows the highway on-ramp is coming,
fleet telemetry knows how long a parking manoeuvre dwells, and the
scenario's own Markov structure says which context follows which.  A
:class:`ModeForecaster` turns that structure into
:class:`ModeForecast`s — *"mode X ends near time t, mode Y follows,
with confidence c"* — which the predictive replanner converts into
pre-staged schedule swaps inside the bounded-reallocation window
*before* the seam.

Two information sources compose:

* **Markov structure** — a mode-transition matrix plus per-mode dwell
  priors (e.g. the scenario generator's own matrix, or empirical
  bigram counts from a script).  The forecast target is the most
  likely non-self successor; the switch time is the dwell estimate;
  confidence is the successor probability discounted by the dwell
  spread.
* **Route timeline** (optional) — any object with
  ``next_switch(now) -> (switch_s, next_mode) | None`` (in practice a
  :class:`~repro.scenarios.ScenarioScript`).  When present it pins the
  switch *time and target* exactly — the "map data" case — and
  confidence is floored at ``route_confidence``: a planned route's
  next segment is near-certain regardless of how surprising the fleet
  matrix finds it (the Markov row can only *raise* the figure, for
  transitions even more canonical than the route floor).  Route-pinned
  forecasts therefore land in the pre-swap band by default; the blend
  band is mainly exercised by pure Markov forecasting, revert backoff,
  or the hedge-only ablation (``replan_mode="blend"``).

Observed dwell times feed back through :meth:`observe_switch`: each
completed segment updates the per-mode dwell mean and spread (and the
transition counts), so a forecaster running over a long drive converges
to the drive's own rhythm rather than the prior's.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Tuple

__all__ = ["ModeForecast", "ModeForecaster"]


@dataclasses.dataclass(frozen=True)
class ModeForecast:
    """One predicted context switch."""

    issued_at_s: float      # when the forecast was emitted
    mode: str               # the mode it predicts the end of
    target_mode: str        # most likely successor
    switch_at_s: float      # predicted absolute switch time
    confidence: float       # in [0, 1]

    @property
    def horizon_s(self) -> float:
        """How far ahead of the predicted seam this forecast looks."""
        return self.switch_at_s - self.issued_at_s


#: dwell spread assumed for pure priors: the bundled Markov generator
#: draws dwell ~ mean * U(0.5, 1.5), whose coefficient of variation is
#: 1/(2*sqrt(3)) ~= 0.289
_PRIOR_DWELL_CV = 1.0 / (2.0 * math.sqrt(3.0))


class ModeForecaster:
    """Markov + dwell-statistics context-switch forecaster.

    ``transitions`` maps mode -> {successor: weight} (rows need not be
    normalised; self-transitions are ignored for targeting — a
    self-transition extends the dwell, it is not a seam).
    ``mean_dwell_s`` provides per-mode dwell priors; both update online
    via :meth:`observe_switch`.  ``timeline`` optionally supplies exact
    switch times/targets (route knowledge); ``route_confidence`` floors
    the confidence of timeline-pinned forecasts.
    """

    def __init__(
        self,
        transitions: Mapping[str, Mapping[str, float]],
        mean_dwell_s: Mapping[str, float],
        timeline: Optional[object] = None,
        route_confidence: float = 0.95,
        prior_weight: float = 3.0,
    ):
        self.transitions: Dict[str, Dict[str, float]] = {
            m: dict(row) for m, row in transitions.items()
        }
        self.mean_dwell_s: Dict[str, float] = dict(mean_dwell_s)
        self.timeline = timeline
        self.route_confidence = float(route_confidence)
        #: how many pseudo-observations the priors are worth when
        #: blending with observed dwells
        self.prior_weight = float(prior_weight)
        # online dwell statistics: mode -> [n, sum, sum_sq]
        self._dwell_obs: Dict[str, list] = {}
        # online transition counts: (mode, next) -> n
        self._trans_obs: Dict[Tuple[str, str], int] = {}
        self.n_observed = 0

    # -- construction helpers -------------------------------------------
    @classmethod
    def from_generator(
        cls, generator, timeline: Optional[object] = None, **kw
    ) -> "ModeForecaster":
        """Forecaster primed with a
        :class:`~repro.scenarios.MarkovScenarioGenerator`'s own
        transition matrix and dwell means (the fleet-knowledge case)."""
        return cls(generator.transitions, generator.mean_dwell_s,
                   timeline=timeline, **kw)

    @classmethod
    def from_script(
        cls, script, use_timeline: bool = True, **kw
    ) -> "ModeForecaster":
        """Forecaster primed with a script's empirical bigram structure
        (see ``ScenarioScript.empirical_transitions``); with
        ``use_timeline`` the script also pins exact switch times (the
        route-informed case)."""
        trans, dwell = script.empirical_transitions()
        return cls(trans, dwell,
                   timeline=script if use_timeline else None, **kw)

    # -- online updates --------------------------------------------------
    def observe_switch(self, mode: str, next_mode: str, dwell_s: float) -> None:
        """Record one completed segment: ``mode`` dwelt ``dwell_s``
        seconds, then switched to ``next_mode``."""
        rec = self._dwell_obs.setdefault(mode, [0, 0.0, 0.0])
        rec[0] += 1
        rec[1] += dwell_s
        rec[2] += dwell_s * dwell_s
        self._trans_obs[(mode, next_mode)] = (
            self._trans_obs.get((mode, next_mode), 0) + 1
        )
        self.n_observed += 1

    # -- estimates -------------------------------------------------------
    def dwell_estimate(self, mode: str) -> Tuple[float, float]:
        """``(mean, cv)`` dwell estimate for ``mode``: the prior blended
        with online observations at ``prior_weight`` pseudo-counts."""
        prior_mean = float(self.mean_dwell_s.get(mode, 0.0))
        n, s, ss = self._dwell_obs.get(mode, (0, 0.0, 0.0))
        if prior_mean <= 0.0 and n == 0:
            return 0.0, _PRIOR_DWELL_CV
        w = self.prior_weight if prior_mean > 0.0 else 0.0
        mean = (w * prior_mean + s) / max(w + n, 1e-12)
        if n >= 2:
            var_obs = max(ss / n - (s / n) ** 2, 0.0)
            cv_obs = math.sqrt(var_obs) / max(s / n, 1e-12)
            cv = (w * _PRIOR_DWELL_CV + n * cv_obs) / (w + n)
        else:
            cv = _PRIOR_DWELL_CV
        return mean, cv

    def successor_probs(self, mode: str) -> Dict[str, float]:
        """Normalised successor distribution for ``mode`` excluding the
        self-transition, blending the prior row with observed counts."""
        row = dict(self.transitions.get(mode, {}))
        total_prior = sum(v for k, v in row.items() if k != mode)
        out: Dict[str, float] = {}
        for (m, nxt), n in self._trans_obs.items():
            if m == mode and nxt != mode:
                out[nxt] = out.get(nxt, 0.0) + float(n)
        n_obs = sum(out.values())
        if total_prior > 0.0:
            w = self.prior_weight
            for k, v in row.items():
                if k != mode:
                    out[k] = out.get(k, 0.0) + w * (v / total_prior)
            n_obs += w
        if n_obs <= 0.0:
            return {}
        return {k: v / n_obs for k, v in out.items()}

    # -- the forecast ----------------------------------------------------
    def forecast(
        self, mode: str, entered_at_s: float, now_s: Optional[float] = None
    ) -> Optional[ModeForecast]:
        """Predict the end of the current ``mode`` segment (entered at
        ``entered_at_s``).  Returns ``None`` when the structure offers
        no successor (absorbing mode, empty row)."""
        now = entered_at_s if now_s is None else now_s
        probs = self.successor_probs(mode)

        if self.timeline is not None:
            nxt = self.timeline.next_switch(now)
            if nxt is None:
                return None
            switch_at, target = nxt
            conf = max(probs.get(target, 0.0), self.route_confidence)
            return ModeForecast(now, mode, target, switch_at, min(conf, 1.0))

        if not probs:
            return None
        target = max(sorted(probs), key=lambda k: probs[k])
        mean, cv = self.dwell_estimate(mode)
        if mean <= 0.0:
            return None
        switch_at = entered_at_s + mean
        # past the expected switch and still in `mode`: the seam is
        # overdue — predict it imminent rather than in the past
        if switch_at <= now:
            switch_at = now + max(0.1 * mean, 1e-3)
        conf = probs[target] * max(0.0, 1.0 - cv)
        return ModeForecast(now, mode, target, switch_at, min(conf, 1.0))
