"""Probabilistic latency model (paper §II-C3, Eq. 1).

Two random variables capture runtime variation:

* **F1 — execution variation** ``W_v``: arithmetic workload of task ``v``
  (FLOPs).  Modelled lognormal, parameterised by its mean and the
  p99/mean ratio (the paper cites p99 up to 3.3x the mean [4]).
* **F2 — inter-task interference** ``I_v``: I/O latency under memory
  contention.  Per the paper, a constant component (avg tile-to-MC hop
  latency) plus an M/M/1 queuing component — a *shifted exponential*
  whose tail grows with DRAM utilisation.

Given ``c_v`` tiles and per-tile processing power ``P``::

    L_v(q, c_v) = W_v^(q) / (c_v * P) + I_v^(q)            (Eq. 1)

so ``Pr[L_v <= L_v(q, c_v)] >= q`` — an independent per-task
probabilistic bound.  On top of the paper's form we keep an explicit
DoP-efficiency term ``sync_per_tile_s * (c-1)`` (the "modulo NoC
communication overhead" caveat of §II-C1): it gives every task a
diminishing-returns DoP curve and therefore a finite optimal DoP, which
the multi-version compiler prunes against (§IV-D2).

Scalar quantiles use plain floats (consumed by the offline GHA solver);
sampling is JAX-vectorised (used by the Monte-Carlo tail-composition
analysis and by the simulator's trace generator).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hardware import HardwareModel
from .workload import SensorTask, Task, Workflow

__all__ = [
    "LogNormal",
    "ShiftedExponential",
    "TaskLatencyProfile",
    "LatencyModel",
    "ndtri",
    "prune_dop_candidates",
    "chain_tail_composition",
]

_Z99 = 2.3263478740408408  # Phi^{-1}(0.99)


@dataclasses.dataclass(frozen=True)
class LogNormal:
    """Lognormal parameterised by (mean, p99/mean ratio)."""

    mean: float
    p99_ratio: float = 1.0

    def __post_init__(self) -> None:
        if self.mean < 0:
            raise ValueError("mean must be >= 0")
        if self.p99_ratio < 1.0:
            raise ValueError("p99_ratio must be >= 1")

    @property
    def sigma(self) -> float:
        if self.p99_ratio <= 1.0 + 1e-12:
            return 0.0
        # p99/mean = exp(z99*s - s^2/2)  =>  s^2 - 2 z99 s + 2 ln r = 0
        lr = math.log(self.p99_ratio)
        disc = _Z99 * _Z99 - 2.0 * lr
        if disc <= 0:  # ratio too extreme for lognormal; saturate
            return _Z99
        return _Z99 - math.sqrt(disc)

    @property
    def mu(self) -> float:
        if self.mean == 0:
            return -math.inf
        return math.log(self.mean) - 0.5 * self.sigma**2

    def quantile(self, q: float) -> float:
        if self.mean == 0:
            return 0.0
        if self.sigma == 0.0:
            return self.mean
        z = float(_ndtri(q))
        return math.exp(self.mu + self.sigma * z)

    def quantiles(self, q: "np.ndarray") -> "np.ndarray":
        """Vectorized :meth:`quantile` over an array of probabilities
        (the batched trace generator's inverse-CDF sampling path)."""
        q = np.asarray(q, dtype=np.float64)
        if self.mean == 0:
            return np.zeros_like(q)
        if self.sigma == 0.0:
            return np.full_like(q, self.mean)
        return np.exp(self.mu + self.sigma * ndtri(q))

    def sample(self, key: jax.Array, shape: Tuple[int, ...] = ()) -> jax.Array:
        if self.mean == 0:
            return jnp.zeros(shape)
        z = jax.random.normal(key, shape)
        return jnp.exp(self.mu + self.sigma * z)


@dataclasses.dataclass(frozen=True)
class ShiftedExponential:
    """base + Exp(rate): the M/M/1 sojourn-tail model of the paper."""

    base: float          # seconds (constant hop-latency component)
    rate: float          # 1/seconds; mean queuing delay = 1/rate

    def quantile(self, q: float) -> float:
        if self.rate <= 0:
            return self.base
        return self.base - math.log(max(1.0 - q, 1e-300)) / self.rate

    def quantiles(self, q: "np.ndarray") -> "np.ndarray":
        """Vectorized :meth:`quantile` over an array of probabilities."""
        q = np.asarray(q, dtype=np.float64)
        if self.rate <= 0:
            return np.full_like(q, self.base)
        return self.base - np.log(np.maximum(1.0 - q, 1e-300)) / self.rate

    @property
    def mean(self) -> float:
        return self.base + (1.0 / self.rate if self.rate > 0 else 0.0)

    def sample(self, key: jax.Array, shape: Tuple[int, ...] = ()) -> jax.Array:
        e = jax.random.exponential(key, shape)
        return self.base + (e / self.rate if self.rate > 0 else 0.0)


# Acklam inverse-normal-CDF coefficients, shared by the scalar fast
# path and the vectorized array path (one implementation of the
# rational approximation; two evaluation strategies).
_NDTRI_A = (-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
            1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00)
_NDTRI_B = (-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
            6.680131188771972e01, -1.328068155288572e01)
_NDTRI_C = (-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
            -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00)
_NDTRI_D = (7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
            3.754408661907416e00)
_NDTRI_PLOW = 0.02425


def _ndtri_tail(x):
    """Tail branch of Acklam's approximation in ``x = sqrt(-2 ln p)``
    (works on floats and on NumPy arrays alike)."""
    c, d = _NDTRI_C, _NDTRI_D
    return (((((c[0] * x + c[1]) * x + c[2]) * x + c[3]) * x + c[4]) * x + c[5]) / \
           ((((d[0] * x + d[1]) * x + d[2]) * x + d[3]) * x + 1)


def _ndtri_central(q):
    """Central branch of Acklam's approximation (floats or arrays)."""
    a, b = _NDTRI_A, _NDTRI_B
    x = q - 0.5
    r = x * x
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * x / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)


def ndtri(q):
    """Inverse standard-normal CDF (Acklam's rational approximation).

    Accepts a float (returned as ``float``, the offline solvers' scalar
    path) or a NumPy array (returned as ``ndarray``, the batched
    trace-generation path) — both evaluate the same branch polynomials.
    ``q <= 0`` maps to ``-inf`` and ``q >= 1`` to ``+inf``.
    """
    if np.ndim(q) == 0:
        q = float(q)
        if not 0.0 < q < 1.0:
            return -math.inf if q <= 0.0 else math.inf
        if q < _NDTRI_PLOW:
            return float(_ndtri_tail(math.sqrt(-2 * math.log(q))))
        if q > 1 - _NDTRI_PLOW:
            return float(-_ndtri_tail(math.sqrt(-2 * math.log(1 - q))))
        return float(_ndtri_central(q))

    q = np.asarray(q, dtype=np.float64)
    out = np.empty_like(q)
    lo = q <= 0.0
    hi = q >= 1.0
    low_tail = (q < _NDTRI_PLOW) & ~lo
    high_tail = (q > 1 - _NDTRI_PLOW) & ~hi
    central = ~(lo | hi | low_tail | high_tail)
    out[lo] = -np.inf
    out[hi] = np.inf
    if low_tail.any():
        out[low_tail] = _ndtri_tail(np.sqrt(-2.0 * np.log(q[low_tail])))
    if high_tail.any():
        out[high_tail] = -_ndtri_tail(np.sqrt(-2.0 * np.log(1.0 - q[high_tail])))
    if central.any():
        out[central] = _ndtri_central(q[central])
    return out


#: backwards-compatible scalar alias (existing callers import `_ndtri`)
_ndtri = ndtri


@dataclasses.dataclass(frozen=True)
class TaskLatencyProfile:
    """Per-task (W_v, I_v) pair plus the DoP-efficiency term."""

    name: str
    work: LogNormal                 # FLOPs (zero for sensor tasks)
    io: ShiftedExponential          # seconds
    sync_per_tile_s: float = 0.0    # NoC/collective overhead per extra tile
    sensor_latency: Optional[LogNormal] = None  # set for sensor tasks

    @property
    def is_sensor(self) -> bool:
        return self.sensor_latency is not None

    # -- Eq. (1) ----------------------------------------------------------
    def latency_bound(self, q: float, c: int, tile_flops: float) -> float:
        """L_v(q, c_v): the per-task probabilistic latency bound."""
        if self.is_sensor:
            return self.sensor_latency.quantile(q)
        compute = self.work.quantile(q) / (c * tile_flops)
        return compute + self.sync_per_tile_s * (c - 1) + self.io.quantile(q)

    def mean_latency(self, c: int, tile_flops: float) -> float:
        if self.is_sensor:
            return self.sensor_latency.mean
        return (self.work.mean / (c * tile_flops)
                + self.sync_per_tile_s * (c - 1) + self.io.mean)

    def sample_latency(
        self, key: jax.Array, c: int, tile_flops: float, shape: Tuple[int, ...] = ()
    ) -> jax.Array:
        if self.is_sensor:
            return self.sensor_latency.sample(key, shape)
        kw, ki = jax.random.split(key)
        w = self.work.sample(kw, shape)
        i = self.io.sample(ki, shape)
        return w / (c * tile_flops) + self.sync_per_tile_s * (c - 1) + i


def prune_dop_candidates(
    profile: TaskLatencyProfile,
    tile_flops: float,
    candidates: Sequence[int],
    q: float = 0.95,
    improvement_threshold: float = 0.05,
) -> Tuple[int, ...]:
    """Multi-version compilation pruning (§IV-D2): gradually increase the
    tile count from the minimum and prune candidates that do not improve
    latency by at least ``improvement_threshold`` over the previous kept
    candidate."""
    cands = sorted(set(int(c) for c in candidates))
    if not cands:
        raise ValueError("no DoP candidates")
    kept = [cands[0]]
    last = profile.latency_bound(q, cands[0], tile_flops)
    for c in cands[1:]:
        lat = profile.latency_bound(q, c, tile_flops)
        if lat < last * (1.0 - improvement_threshold):
            kept.append(c)
            last = lat
    return tuple(kept)


class LatencyModel:
    """The framework's latency oracle: profiles for every task of a
    workflow on a given hardware model."""

    def __init__(self, profiles: Mapping[str, TaskLatencyProfile], hw: HardwareModel):
        self.profiles: Dict[str, TaskLatencyProfile] = dict(profiles)
        self.hw = hw
        # (task, q, c) -> L_v(q, c): profiles are frozen, so bounds are
        # immutable per model.  best_dop / min_dop_for_budget / the GHA
        # phases and the portfolio autotuner recompute the same bounds
        # many times per compile; the cache makes repeats a dict hit.
        self._bound_cache: Dict[Tuple[str, float, int], float] = {}
        # (task, q, candidate tuple) -> bound tuple: the frontier search
        # walks whole candidate ladders per (task, q); see bound_ladder
        self._ladder_cache: Dict[Tuple[str, float, tuple], Tuple[float, ...]] = {}
        # task tuple -> flattened per-task parameter arrays for the
        # vectorized bound_batch path (see _batch_params)
        self._batch_cache: Dict[Tuple[str, ...], tuple] = {}

    # -- construction ------------------------------------------------------
    @classmethod
    def from_workflow(
        cls,
        wf: Workflow,
        hw: HardwareModel,
        p99_ratio: float = 3.3,
        dram_utilization: float = 0.5,
        base_io_s: float = 5e-6,
        sensor_p99_ratio: float = 1.5,
    ) -> "LatencyModel":
        """Build profiles from the workflow's per-task annotations.

        The M/M/1 queuing rate for task v shrinks as total DRAM pressure
        grows: ``rate = k_v * (1 - rho)`` with ``k_v`` set so that a task
        demanding a larger bandwidth share queues longer (its requests
        arrive faster).  This mirrors the paper's BookSim-fitted I_v whose
        tail grows with DRAM utilisation.
        """
        rho = min(max(dram_utilization, 0.0), 0.99)
        profiles: Dict[str, TaskLatencyProfile] = {}
        for name, task in wf.tasks.items():
            if isinstance(task, SensorTask):
                profiles[name] = TaskLatencyProfile(
                    name=name,
                    work=LogNormal(0.0),
                    io=ShiftedExponential(0.0, 0.0),
                    sensor_latency=LogNormal(task.mean_latency_s, sensor_p99_ratio),
                )
                continue
            # queuing: heavier-bandwidth tasks see longer queues
            bw_share = max(task.avg_bw_frac, 0.005)
            service_rate = 1.0 / base_io_s
            rate = service_rate * (1.0 - rho) / (1.0 + 10.0 * bw_share)
            # sync term: moving one job's activation set across one more
            # tile costs checkpoint_bytes/100 over a NoC link
            sync = (0.01 * task.checkpoint_bytes) / hw.noc_link_bytes_per_s
            profiles[name] = TaskLatencyProfile(
                name=name,
                work=LogNormal(task.mean_flops, p99_ratio),
                io=ShiftedExponential(base_io_s, rate),
                sync_per_tile_s=sync,
            )
        return cls(profiles, hw)

    # -- queries -----------------------------------------------------------
    def bound(self, task: str, q: float, c: int) -> float:
        """Cached L_v(q, c) (Eq. 1); see ``_bound_cache``."""
        key = (task, q, c)
        hit = self._bound_cache.get(key)
        if hit is None:
            hit = self.profiles[task].latency_bound(q, c, self.hw.tile_flops)
            self._bound_cache[key] = hit
        return hit

    def mean(self, task: str, c: int) -> float:
        return self.profiles[task].mean_latency(c, self.hw.tile_flops)

    def bound_ladder(
        self, task: str, q: float, cands: Tuple[int, ...]
    ) -> Tuple[float, ...]:
        """L_v(q, c) for a whole DoP-candidate tuple at one (task, q).

        The per-(task, q) quantiles ``W_v^(q)`` and ``I_v^(q)`` are
        computed once and the ladder over ``c`` is filled arithmetically
        — the autotuner's frontier search and the solvers' candidate
        walks re-evaluate the same ladders constantly, and computing
        ``ndtri`` per rung was the dominant cost.  Memoized per
        ``(task, q, cands)``.
        """
        key = (task, q, cands)
        hit = self._ladder_cache.get(key)
        if hit is not None:
            return hit
        prof = self.profiles[task]
        if prof.is_sensor:
            lat = prof.sensor_latency.quantile(q)
            out = tuple(lat for _ in cands)
        else:
            wq = prof.work.quantile(q)
            iq = prof.io.quantile(q)
            tf = self.hw.tile_flops
            sync = prof.sync_per_tile_s
            out = tuple(wq / (c * tf) + sync * (c - 1) + iq for c in cands)
        self._ladder_cache[key] = out
        bc = self._bound_cache
        for c, l in zip(cands, out):
            bc.setdefault((task, q, c), l)
        return out

    def _batch_params(self, tasks: Tuple[str, ...]) -> tuple:
        """Per-task distribution parameters flattened to arrays for
        :meth:`bound_batch` (cached per task tuple)."""
        hit = self._batch_cache.get(tasks)
        if hit is not None:
            return hit
        n = len(tasks)
        mean = np.empty(n)
        mu = np.empty(n)
        sigma = np.empty(n)
        io_base = np.empty(n)
        io_rate = np.empty(n)
        sync = np.empty(n)
        sensor = np.zeros(n, dtype=bool)
        for i, t in enumerate(tasks):
            prof = self.profiles[t]
            dist = prof.sensor_latency if prof.is_sensor else prof.work
            mean[i] = dist.mean
            mu[i] = dist.mu if dist.mean > 0 else 0.0
            sigma[i] = dist.sigma
            io_base[i] = prof.io.base
            io_rate[i] = prof.io.rate
            sync[i] = prof.sync_per_tile_s
            sensor[i] = prof.is_sensor
        params = (mean, mu, sigma, io_base, io_rate, sync, sensor)
        self._batch_cache[tasks] = params
        return params

    def bound_batch(
        self, tasks: Tuple[str, ...], q: float, dops: "np.ndarray"
    ) -> "np.ndarray":
        """Vectorized Eq. (1) across many tasks at one quantile.

        ``dops`` aligns with ``tasks`` (ignored for sensor entries,
        which evaluate their sensor-latency quantile).  This is the
        frontier search's inner loop: predicting a schedule's E2E miss
        probability bisects over ``q`` with the chain's task set fixed,
        so per-call work must be a handful of array ops, not a Python
        loop over :meth:`bound`.
        """
        mean, mu, sigma, io_base, io_rate, sync, sensor = self._batch_params(tasks)
        z = float(_ndtri(q))
        with np.errstate(invalid="ignore"):
            wq = np.where(sigma > 0.0, np.exp(mu + sigma * z), mean)
        wq = np.where(mean <= 0.0, 0.0, wq)
        c = np.maximum(np.asarray(dops, dtype=np.float64), 1.0)
        iq = io_base + np.where(
            io_rate > 0.0,
            -math.log(max(1.0 - q, 1e-300)) / np.maximum(io_rate, 1e-300),
            0.0,
        )
        dnn = wq / (c * self.hw.tile_flops) + sync * (c - 1.0) + iq
        return np.where(sensor, wq, dnn)

    def best_dop(self, task: Task, q: float, cap: Optional[int] = None) -> int:
        """Smallest-latency DoP among the (pruned) candidates."""
        cands = task.dop_candidates(cap)
        ladder = self.bound_ladder(task.name, q, cands)
        best = min(range(len(cands)), key=lambda i: ladder[i])
        return cands[best]

    def min_dop_for_budget(
        self, task: Task, q: float, budget_s: float, cap: Optional[int] = None
    ) -> Optional[int]:
        """Smallest DoP whose q-quantile bound fits in ``budget_s``
        (the FitQuota primitive of Alg. 2); None if infeasible."""
        cands = task.dop_candidates(cap)
        for c, l in zip(cands, self.bound_ladder(task.name, q, cands)):
            if l <= budget_s:
                return c
        return None

    def pruned_candidates(
        self, task: Task, q: float = 0.95, threshold: float = 0.05
    ) -> Tuple[int, ...]:
        return prune_dop_candidates(
            self.profiles[task.name], self.hw.tile_flops,
            task.dop_candidates(), q, threshold,
        )


def chain_tail_composition(
    model: LatencyModel,
    chain_tasks: Sequence[str],
    dops: Mapping[str, int],
    q: float,
    num_samples: int = 20000,
    seed: int = 0,
) -> Dict[str, float]:
    """Quantify the *tail-composition headroom* (paper §II-C3 scope note).

    Summing per-task q-quantile budgets overestimates the observed E2E
    q-quantile because tail events from different tasks rarely align in
    the same chain instance.  Returns the conservative envelope
    ``sum_q`` = sum of per-task bounds, the Monte-Carlo E2E quantile
    ``mc_q``, and headroom = 1 - mc_q/sum_q.

    JAX-vectorised: one `vmap`-free batched sample per task, summed.
    """
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(chain_tasks))
    total = jnp.zeros((num_samples,))
    sum_q = 0.0
    tf = model.hw.tile_flops
    for k, name in zip(keys, chain_tasks):
        prof = model.profiles[name]
        c = int(dops.get(name, 1))
        total = total + prof.sample_latency(k, c, tf, (num_samples,))
        sum_q += prof.latency_bound(q, c, tf)
    mc_q = float(jnp.quantile(total, q))
    mc_mean = float(jnp.mean(total))
    return {
        "sum_of_quantiles_s": float(sum_q),
        "mc_quantile_s": mc_q,
        "mc_mean_s": mc_mean,
        "headroom": 1.0 - mc_q / sum_q if sum_q > 0 else 0.0,
    }
