"""Workload model: DAG, periodic sensors, chains, hyper-period (paper §II-C2).

An ADS workflow is a DAG ``G(V, E)`` with ``V = V_sen ∪ V_dnn``.  Sensor
tasks are activated by hardware timers at strictly periodic rates; DNN
tasks are data-driven (ready when all predecessors complete).  Because all
data originates from periodic sensors, dependency patterns repeat over the
hyper-period ``T_hp = lcm{T_v}`` and the DAG unrolls into task *instances*
with a static dependency structure (Fig. 2b-c).

Times are in **seconds** throughout the core.
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from fractions import Fraction
from functools import reduce
from typing import (
    Dict, Iterable, List, Mapping, Optional, Sequence, Tuple, Union,
)

__all__ = [
    "Task",
    "SensorTask",
    "DnnTask",
    "Chain",
    "Workflow",
    "TaskInstance",
    "unroll_hyperperiod",
    "clear_unroll_cache",
]


@dataclasses.dataclass(frozen=True)
class Task:
    """A node of the workflow DAG."""

    name: str
    # mean arithmetic workload per job, in FLOPs (W_v's location parameter)
    mean_flops: float = 0.0
    # bytes checkpointed on a DoP switch (weights + live features)
    checkpoint_bytes: float = 0.0
    # mean fraction of aggregate DRAM bandwidth this task consumes (Fig. 10)
    avg_bw_frac: float = 0.0
    # peak instantaneous DRAM bandwidth demand, bytes/s (Fig. 10)
    peak_bw: float = 0.0
    # valid pre-compiled DoP candidates (c_v^compiled); empty = any in range
    compiled_dops: Tuple[int, ...] = ()
    # inclusive DoP bounds when compiled_dops is empty
    min_dop: int = 1
    max_dop: int = 64
    # model family tag (for reporting only)
    model: str = ""

    @property
    def is_sensor(self) -> bool:
        return False

    def dop_candidates(self, cap: Optional[int] = None) -> Tuple[int, ...]:
        cands = self.compiled_dops or tuple(range(self.min_dop, self.max_dop + 1))
        if cap is not None:
            kept = tuple(c for c in cands if c <= cap)
            cands = kept or (min(cands),)
        return cands


@dataclasses.dataclass(frozen=True)
class SensorTask(Task):
    """Periodic source task, executed on a dedicated SPE (not on tiles)."""

    period_s: float = 0.1  # 1/rate
    # preprocessing latency distribution handled by the latency model;
    # mean latency kept here for quick estimates.
    mean_latency_s: float = 1e-3

    @property
    def is_sensor(self) -> bool:
        return True

    @property
    def rate_hz(self) -> float:
        return 1.0 / self.period_s


@dataclasses.dataclass(frozen=True)
class DnnTask(Task):
    """Data-driven DNN inference task running on tiles."""


@dataclasses.dataclass(frozen=True)
class Chain:
    """An end-to-end chain: sensor source -> ... -> actuator/display sink."""

    name: str
    nodes: Tuple[str, ...]            # task names, topological along the path
    deadline_s: float                 # E2E latency constraint D_e2e
    critical: bool = False            # safety-critical (driving) vs cockpit

    def __post_init__(self) -> None:
        if len(self.nodes) < 2:
            raise ValueError(f"chain {self.name} needs >=2 nodes")


def _lcm(values: Iterable[int]) -> int:
    return reduce(math.lcm, values, 1)


@dataclasses.dataclass
class Workflow:
    """The workflow DAG with its E2E chains."""

    tasks: Dict[str, Task]
    edges: List[Tuple[str, str]]
    chains: List[Chain]

    def __post_init__(self) -> None:
        for u, v in self.edges:
            if u not in self.tasks or v not in self.tasks:
                raise ValueError(f"edge ({u},{v}) references unknown task")
        for ch in self.chains:
            for n in ch.nodes:
                if n not in self.tasks:
                    raise ValueError(f"chain {ch.name} references unknown task {n}")
            for a, b in zip(ch.nodes, ch.nodes[1:]):
                if (a, b) not in set(self.edges):
                    raise ValueError(
                        f"chain {ch.name}: ({a},{b}) is not an edge of G"
                    )
        self._preds: Dict[str, List[str]] = {n: [] for n in self.tasks}
        self._succs: Dict[str, List[str]] = {n: [] for n in self.tasks}
        for u, v in self.edges:
            self._preds[v].append(u)
            self._succs[u].append(v)
        self._check_acyclic()
        # hot-path caches (the simulator queries these per job / per
        # completion): chain membership, chain sinks, tightest E2E
        # deadline offsets, task rates, the hyper-period, and the
        # structural signature used as the unroll/skeleton cache key.
        self._chains_of: Dict[str, List[Chain]] = {
            n: [c for c in self.chains if n in c.nodes] for n in self.tasks
        }
        self._chains_ending: Dict[str, List[Chain]] = {
            n: [c for c in self._chains_of[n] if c.nodes[-1] == n]
            for n in self.tasks
        }
        self._ddl_off: Dict[str, float] = {
            n: min((c.deadline_s for c in self._chains_of[n]), default=math.inf)
            for n in self.tasks
        }
        self._rate_cache: Dict[str, float] = {}
        self._hp_cache: Optional[float] = None
        self._signature: Optional[tuple] = None

    # -- graph helpers ----------------------------------------------------
    def preds(self, name: str) -> List[str]:
        return self._preds[name]

    def succs(self, name: str) -> List[str]:
        return self._succs[name]

    @property
    def sensor_tasks(self) -> List[SensorTask]:
        return [t for t in self.tasks.values() if isinstance(t, SensorTask)]

    @property
    def dnn_tasks(self) -> List[Task]:
        return [t for t in self.tasks.values() if not t.is_sensor]

    def topological_order(self) -> List[str]:
        indeg = {n: len(self._preds[n]) for n in self.tasks}
        ready = sorted(n for n, d in indeg.items() if d == 0)
        order: List[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for s in sorted(self._succs[n]):
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
            ready.sort()
        return order

    def _check_acyclic(self) -> None:
        if len(self.topological_order()) != len(self.tasks):
            raise ValueError("workflow graph has a cycle")

    # -- timing -----------------------------------------------------------
    @property
    def hyper_period_s(self) -> float:
        """T_hp = lcm of the sensor periods (exact rational arithmetic —
        1/30 s is not integral in any fixed unit)."""
        if self._hp_cache is not None:
            return self._hp_cache
        if not self.sensor_tasks:
            raise ValueError("workflow has no sensor tasks")
        fracs = [Fraction(t.period_s).limit_denominator(10**9) for t in self.sensor_tasks]
        num = _lcm(f.numerator for f in fracs)
        den = reduce(math.gcd, (f.denominator for f in fracs))
        self._hp_cache = float(Fraction(num, den))
        return self._hp_cache

    @property
    def structural_signature(self) -> tuple:
        """Hashable identity of everything the unrolled instance graph
        depends on: tasks (with sensor periods), edges, and chains.  Two
        workflows with equal signatures unroll identically, so this is
        the cache key for :func:`unroll_hyperperiod` memoization and for
        the simulator's trace-skeleton cache (mode transforms build a
        *new* ``Workflow`` per call, so identity comparison is useless
        across runs)."""
        if self._signature is None:
            self._signature = (
                tuple(sorted(
                    (t.name, t.period_s if t.is_sensor else None)
                    for t in self.tasks.values()
                )),
                tuple(self.edges),
                tuple((c.name, c.nodes, c.deadline_s) for c in self.chains),
            )
        return self._signature

    def task_rate_hz(self, name: str) -> float:
        """Effective activation rate of a task: max of its source sensor
        rates along any path (a DNN task fires when all predecessors have a
        fresh job; the slowest upstream sensor gates the rate, matching the
        event-time alignment of §IV-C)."""
        cached = self._rate_cache.get(name)
        if cached is not None:
            return cached
        task = self.tasks[name]
        if isinstance(task, SensorTask):
            rate = task.rate_hz
        else:
            preds = self._preds[name]
            if not preds:
                raise ValueError(f"DNN task {name} has no predecessors")
            rate = min(self.task_rate_hz(p) for p in preds)
        self._rate_cache[name] = rate
        return rate

    def chain_for(self, name: str) -> List[Chain]:
        return self._chains_of[name]

    def chains_ending_at(self, name: str) -> List[Chain]:
        """Chains whose sink is ``name`` (the simulator's completion
        accounting runs this per finished job)."""
        return self._chains_ending[name]

    def deadline_offset(self, name: str) -> float:
        """Tightest E2E deadline through ``name`` over all its chains
        (``inf`` for tasks on no chain)."""
        return self._ddl_off[name]

    @property
    def sensor_periods(self) -> Dict[str, float]:
        """``{sensor name: period_s}`` — the rate signature of the
        workflow (two workflows with equal signatures unroll alike)."""
        return {t.name: t.period_s for t in self.sensor_tasks}

    def with_sensor_rates(self, periods: Mapping[str, float]) -> "Workflow":
        """Re-derive the workflow with new sensor periods (per-mode rate
        modulation: camera 30->15 Hz at night, radar 10->20 Hz in rain).

        ``periods`` maps sensor task names to their new ``period_s``;
        the DAG, chains and every DNN task are untouched.  Returns
        ``self`` when nothing effectively changes, so regime detection
        can compare identity cheaply.
        """
        for name, p in periods.items():
            task = self.tasks.get(name)
            if task is None or not task.is_sensor:
                raise ValueError(f"{name!r} is not a sensor task")
            if p <= 0:
                raise ValueError(f"{name}: non-positive period {p}")
        changed = {
            n: float(p) for n, p in periods.items()
            if not math.isclose(self.tasks[n].period_s, p, rel_tol=1e-12)
        }
        if not changed:
            return self
        tasks = dict(self.tasks)
        for n, p in changed.items():
            tasks[n] = dataclasses.replace(tasks[n], period_s=p)
        return Workflow(tasks=tasks, edges=list(self.edges), chains=list(self.chains))

    def replicate_cockpit(self, factor: int, cockpit_chain_names: Sequence[str]) -> "Workflow":
        """Scale workload by replicating cockpit pipelines (paper §V-A,
        nodes 11-14).  A node is replicated only if *every* chain it
        belongs to is being replicated — shared upstream stages (image
        backbones, sensors) stay shared across replicas."""
        if factor <= 1:
            return self
        cockpit = set(cockpit_chain_names)
        replicable = {
            n for n in self.tasks
            if not self.tasks[n].is_sensor
            and (cs := self.chain_for(n))
            and all(c.name in cockpit for c in cs)
        }
        tasks = dict(self.tasks)
        edges = list(self.edges)
        chains = list(self.chains)
        for k in range(1, factor):
            for cname in cockpit_chain_names:
                chain = next(c for c in self.chains if c.name == cname)
                mapping: Dict[str, str] = {}
                for node in chain.nodes:
                    if node not in replicable:
                        mapping[node] = node  # shared stage
                        continue
                    new_name = f"{node}#r{k}"
                    mapping[node] = new_name
                    if new_name not in tasks:
                        tasks[new_name] = dataclasses.replace(
                            self.tasks[node], name=new_name
                        )
                for a, b in zip(chain.nodes, chain.nodes[1:]):
                    e = (mapping[a], mapping[b])
                    if e not in edges:
                        edges.append(e)
                chains.append(
                    dataclasses.replace(
                        chain,
                        name=f"{cname}#r{k}",
                        nodes=tuple(mapping[n] for n in chain.nodes),
                    )
                )
        return Workflow(tasks=tasks, edges=edges, chains=chains)


@dataclasses.dataclass(frozen=True)
class TaskInstance:
    """One job of a task inside the hyper-period (e.g. A0, A1 in Fig. 2)."""

    task: str
    index: int                        # 0..N_v-1
    release_s: float                  # activation offset within T_hp
    preds: Tuple[Tuple[str, int], ...]  # (task, index) instance-level deps

    @property
    def key(self) -> Tuple[str, int]:
        return (self.task, self.index)


#: memoized unroll segments keyed on (structural signature, t0, t1,
#: phase).  Monte-Carlo sweeps re-unroll the same workflow segments for
#: every policy / replan variant / scenario sharing a regime; the cache
#: makes repeats free.  Bounded FIFO so unbounded scenario diversity
#: cannot leak memory.  Cached lists are shared — callers must treat
#: them as immutable (TaskInstance is frozen; the engine only iterates).
_UNROLL_CACHE: "OrderedDict[tuple, List[TaskInstance]]" = OrderedDict()
_UNROLL_CACHE_MAX = 256


def clear_unroll_cache() -> None:
    """Drop all memoized unroll segments (test isolation hook)."""
    _UNROLL_CACHE.clear()


def unroll_hyperperiod(
    wf: Workflow,
    t0: float = 0.0,
    t1: Optional[float] = None,
    phase_s: Union[float, Mapping[str, float]] = 0.0,
) -> List[TaskInstance]:
    """Unroll the DAG over a segment ``[t0, t1)`` (paper §II-C2).

    With the defaults this is one hyper-period starting at 0: each task
    ``v`` decomposes into ``N_v = T_hp / T_v`` instances.  A DNN instance
    depends on the *latest* instance of each predecessor released at or
    before its own release (event-time matching, §IV-C).

    Passing ``t0``/``t1`` unrolls an arbitrary segment with *absolute*
    release times: sensor timers are re-anchored at ``t0 + phase_s``
    (``phase_s`` is normalised into one period), which is what a
    mid-run sensor-rate change does — the hardware timers restart at
    the regime boundary, and the piecewise unrollings on either side
    share no instances (no double-released, no lost jobs).  ``t1 - t0``
    need not be a multiple of the hyper-period.

    ``phase_s`` may also be a mapping ``{sensor name: phase}``: only
    the listed sensors re-anchor at ``t0 + phase``, the rest stay on
    the ``t0`` grid.  This is what a *rate seam* needs — the modulated
    sensor's hardware timer restarts at the seam, but an unmodulated
    sensor keeps its own cadence across it (see
    :func:`repro.core.sim.trace.build_skeleton`); a sensor missing from
    the mapping gets phase 0.
    """
    if t1 is None:
        t1 = t0 + wf.hyper_period_s
    if t1 <= t0:
        raise ValueError(f"empty unroll segment [{t0}, {t1})")
    per_sensor = isinstance(phase_s, Mapping)
    phase_key = (
        tuple(sorted(phase_s.items())) if per_sensor else phase_s
    )
    key = (wf.structural_signature, t0, t1, phase_key)
    cached = _UNROLL_CACHE.get(key)
    if cached is not None:
        _UNROLL_CACHE.move_to_end(key)
        return cached
    instances: List[TaskInstance] = []
    releases: Dict[str, List[float]] = {}

    for name in wf.topological_order():
        task = wf.tasks[name]
        if isinstance(task, SensorTask):
            period = task.period_s
            ph = phase_s.get(name, 0.0) if per_sensor else phase_s
            first = t0 + (ph % period if ph else 0.0)
            n = max(0, int(math.ceil((t1 - first) / period - 1e-9)))
            releases[name] = [
                r for r in (first + i * period for i in range(n))
                if r < t1 - 1e-12
            ]
        else:
            preds = wf.preds(name)
            # release times = those of the rate-gating (slowest) predecessor
            gate = min(preds, key=lambda p: wf.task_rate_hz(p))
            releases[name] = list(releases[gate])

    for name in wf.topological_order():
        task = wf.tasks[name]
        for i, rel in enumerate(releases[name]):
            deps: List[Tuple[str, int]] = []
            if not task.is_sensor:
                for p in wf.preds(name):
                    # latest predecessor instance with release <= rel
                    cand = [j for j, r in enumerate(releases[p]) if r <= rel + 1e-12]
                    if cand:
                        deps.append((p, cand[-1]))
                    # else: the predecessor has not sampled yet in this
                    # segment (possible only with per-sensor phase
                    # offsets); the instance runs without that input
                    # rather than depending on a *future* sample
            instances.append(
                TaskInstance(task=name, index=i, release_s=rel, preds=tuple(deps))
            )
    _UNROLL_CACHE[key] = instances
    while len(_UNROLL_CACHE) > _UNROLL_CACHE_MAX:
        _UNROLL_CACHE.popitem(last=False)
    return instances
