"""Production mesh definition.

Defined as FUNCTIONS (not module-level constants) so importing this
module never touches jax device state — device count is locked on first
jax initialisation, and only ``dryrun.py`` sets the 512-placeholder-
device XLA flag.
"""
from __future__ import annotations


import jax

__all__ = ["make_production_mesh", "make_mesh_for"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single-pod mesh over ('data', 'model'); with
    ``multi_pod=True`` the 2-pod (2, 16, 16) mesh over
    ('pod', 'data', 'model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_mesh_for(num_devices: int, model_parallel: int = 1):
    """Small helper for tests/examples on however many devices exist."""
    data = num_devices // model_parallel
    return jax.make_mesh(
        (data, model_parallel), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
