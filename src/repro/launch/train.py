"""Production training driver: ``python -m repro.launch.train --arch <id>``.

On this CPU container it runs the reduced config by default (the full
configs are exercised via the dry-run); pass ``--full`` on real
hardware.  Demonstrates the whole substrate: sharded data pipeline,
jit'd train step, checkpoint/restart fault tolerance, straggler
monitoring.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.distribution.elastic import StragglerMonitor
from repro.training import TrainConfig, Trainer
from repro.training.data import DataConfig, Prefetcher, synthetic_stream


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4_mini_3p8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — real hardware only")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    tcfg = TrainConfig(
        steps=args.steps,
        checkpoint_dir=args.checkpoint_dir,
        grad_accum=args.grad_accum,
    )
    trainer = Trainer(cfg, tcfg)
    resumed = trainer.restore_if_available()
    if resumed:
        print(f"[train] resumed from step {trainer.step}")

    dcfg = DataConfig(batch=args.batch, seq_len=args.seq_len)
    data = Prefetcher(synthetic_stream(cfg, dcfg, start_step=trainer.step))
    mon = StragglerMonitor()

    def log(rec):
        strag = mon.observe(rec["step"], rec["dt_s"])
        print(
            f"[train] step {rec['step']:5d} loss={rec['loss']:.4f} "
            f"gnorm={rec['grad_norm']:.3f} dt={rec['dt_s']*1e3:.0f}ms"
            + ("  STRAGGLER", "")[not strag]
        )

    result = trainer.fit(data, on_log=log)
    data.close()
    print(f"[train] done at step {result['final_step']}")


if __name__ == "__main__":
    main()
