"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Runs the continuous-batching engine on the reduced config with a burst
of synthetic requests (real hardware serves the full config; the full
configs' serve_step lowering is proven by the dry-run).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import EngineConfig, Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4_mini_3p8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params, EngineConfig(max_batch=args.batch, max_len=128)
    )

    rng = np.random.RandomState(0)
    t0 = time.time()
    reqs = []
    for i in range(args.requests):
        r = Request(
            rid=i,
            prompt=rng.randint(0, cfg.vocab_size, (args.prompt_len,)).astype(np.int32),
            max_new_tokens=args.max_new,
            arrival_s=time.time(),
        )
        reqs.append(r)
        engine.submit(r)

    engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in reqs)
    print(
        f"[serve] {args.arch}: {len(reqs)} requests, {toks} tokens "
        f"in {dt:.2f}s ({toks/dt:.1f} tok/s, batch={args.batch})"
    )
    lat = [r.finish_s - r.arrival_s for r in reqs if r.finish_s]
    print(
        f"[serve] latency p50={np.percentile(lat,50)*1e3:.0f}ms "
        f"p99={np.percentile(lat,99)*1e3:.0f}ms"
    )


if __name__ == "__main__":
    main()
