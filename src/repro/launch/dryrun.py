import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
#   initialisation, and the multi-pod dry-run needs 512 host devices.

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.roofline import roofline_from_compiled  # noqa: E402
from repro.configs import ARCHS, SHAPES, get_config          # noqa: E402
from repro.distribution.sharding import (                    # noqa: E402
    batch_specs, cache_specs, param_specs,
)
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models import LM, init_params                     # noqa: E402
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

__all__ = ["input_specs", "run_cell", "main"]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def input_specs(cfg, shape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = shape.global_batch
    if shape.kind == "train":
        s = shape.seq_len
        if cfg.num_codebooks:
            return {
                "tokens": _sds((b, cfg.num_codebooks, s), jnp.int32),
                "labels": _sds((b, cfg.num_codebooks, s), jnp.int32),
            }
        if cfg.num_patches:
            return {
                "tokens": _sds((b, s - cfg.num_patches), jnp.int32),
                "labels": _sds((b, s - cfg.num_patches), jnp.int32),
                "patch_embeds": _sds(
                    (b, cfg.num_patches, cfg.d_model), cfg.jnp_dtype
                ),
            }
        return {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
    if shape.kind == "prefill":
        s = shape.seq_len
        if cfg.num_codebooks:
            return {"tokens": _sds((b, cfg.num_codebooks, s), jnp.int32)}
        if cfg.num_patches:
            return {
                "tokens": _sds((b, s - cfg.num_patches), jnp.int32),
                "patch_embeds": _sds(
                    (b, cfg.num_patches, cfg.d_model), cfg.jnp_dtype
                ),
            }
        return {"tokens": _sds((b, s), jnp.int32)}
    # decode: one new token
    if cfg.num_codebooks:
        return {"tokens": _sds((b, cfg.num_codebooks, 1), jnp.int32)}
    return {"tokens": _sds((b, 1), jnp.int32)}


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _filter_spec(spec: P, mesh, shape=None) -> P:
    """Drop axes the mesh does not have (single-pod mesh has no 'pod')
    and axes whose size does not divide the dimension (explicit
    ``in_shardings`` require exact divisibility: vocab 50280 cannot
    shard 16-way, a batch of 1 cannot shard over 'data', gemma3's 4 KV
    heads cannot split across 16 model shards)."""
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.shape.values())) if hasattr(
        mesh.shape, "values"
    ) else dict(mesh.shape)
    entries = []
    for i, e in enumerate(spec):
        dim = None if shape is None or i >= len(shape) else shape[i]

        def ok(axes) -> bool:
            if dim is None:
                return True
            prod = 1
            for a in axes:
                prod *= sizes[a]
            return dim % prod == 0

        if e is None:
            entries.append(None)
        elif isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            while kept and not ok(kept):
                kept = kept[1:]  # drop the outermost axis first
            entries.append(kept if kept else None)
        else:
            keep = e in names and ok((e,))
            entries.append(e if keep else None)
    return P(*entries)


def _shardings(mesh, spec_tree, abs_tree=None):
    if abs_tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, _filter_spec(s, mesh)), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, _filter_spec(s, mesh, a.shape)),
        spec_tree, abs_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "status": "SKIP(full-attention)",
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.size
    model = LM(cfg)
    t0 = time.time()

    key = jax.random.PRNGKey(0)
    params_abs = _abstract(lambda: init_params(cfg, key))
    fsdp_train = True
    fsdp_serve = cfg.param_count() * 2 > 16 * (16e9) * 0.5  # deepseek-class
    batch = input_specs(cfg, shape)

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            p_specs = param_specs(cfg, params_abs, fsdp=fsdp_train)
            opt_abs = _abstract(
                lambda: adamw_init(
                    params_abs,
                    "bfloat16" if fsdp_serve else "float32",
                )
            )
            o_specs = {
                "m": p_specs, "v": p_specs, "step": P(),
            }
            b_specs = batch_specs(cfg, batch)
            # deepseek-class models: bf16 optimizer moments (the m/v
            # states dominate per-chip HBM at 236B; Perf iteration 3)
            acfg = AdamWConfig(
                state_dtype="bfloat16" if fsdp_serve else "float32"
            )

            def train_step(params, opt, batch):
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
                new_p, new_o, gn = adamw_update(acfg, params, grads, opt)
                return new_p, new_o, loss, gn

            jitted = jax.jit(
                train_step,
                in_shardings=(
                    _shardings(mesh, p_specs, params_abs),
                    _shardings(mesh, o_specs, opt_abs),
                    _shardings(mesh, b_specs, batch),
                ),
                out_shardings=(
                    _shardings(mesh, p_specs, params_abs),
                    _shardings(mesh, o_specs, opt_abs),
                    NamedSharding(mesh, P()),
                    NamedSharding(mesh, P()),
                ),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, batch)
        elif shape.kind == "prefill":
            p_specs = param_specs(cfg, params_abs, fsdp=fsdp_serve)
            cache_abs = _abstract(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            c_specs = cache_specs(
                cfg, cache_abs, batch_shardable=True,
                model_size=dict(mesh.shape)["model"],
            )
            b_specs = batch_specs(cfg, batch)

            def serve_step(params, batch, cache):
                return model.prefill(params, batch, cache)

            jitted = jax.jit(
                serve_step,
                in_shardings=(
                    _shardings(mesh, p_specs, params_abs),
                    _shardings(mesh, b_specs, batch),
                    _shardings(mesh, c_specs, cache_abs),
                ),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_abs, batch, cache_abs)
        else:  # decode
            p_specs = param_specs(cfg, params_abs, fsdp=fsdp_serve)
            cache_abs = _abstract(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            shardable = shape.global_batch >= 32
            c_specs = cache_specs(
                cfg, cache_abs, batch_shardable=shardable,
                model_size=dict(mesh.shape)["model"],
            )
            b_specs = batch_specs(cfg, batch)
            pos = _sds((), jnp.int32)

            def serve_step(params, batch, cache, pos):
                return model.decode_step(params, batch, cache, pos)

            jitted = jax.jit(
                serve_step,
                in_shardings=(
                    _shardings(mesh, p_specs, params_abs),
                    _shardings(mesh, b_specs, batch),
                    _shardings(mesh, c_specs, cache_abs),
                    NamedSharding(mesh, P()),
                ),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_abs, batch, cache_abs, pos)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    terms = roofline_from_compiled(arch, shape, mesh_name, chips, compiled, cfg)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "status": "OK",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes_per_device": getattr(mem, "alias_size_in_bytes", None),
        },
        "roofline": terms.to_dict(),
    }
    if verbose:
        args_gb = (result["memory"]["argument_bytes_per_device"] or 0) / 1e9
        tmp_gb = (result["memory"]["temp_bytes_per_device"] or 0) / 1e9
        print(
            f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:10s} "
            f"args={args_gb:6.2f}GB temp={tmp_gb:6.2f}GB "
            f"compute={terms.compute_s*1e3:8.2f}ms mem={terms.memory_s*1e3:8.2f}ms "
            f"coll={terms.collective_s*1e3:8.2f}ms dom={terms.dominant:10s} "
            f"lower={t_lower:5.1f}s compile={t_compile:6.1f}s",
            flush=True,
        )
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run sweep")
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                path = outdir / f"{tag}.json"
                if path.exists() and not args.force:
                    cached = json.loads(path.read_text())
                    if not str(cached.get("status", "")).startswith("FAIL"):
                        print(f"[dryrun] cached {tag}")
                        continue  # retry previous failures
                try:
                    res = run_cell(arch, shape, multi_pod=mp)
                except Exception as e:  # record the failure, keep sweeping
                    res = {
                        "arch": arch, "shape": shape,
                        "mesh": "multi" if mp else "single",
                        "status": f"FAIL: {type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    failures.append(tag)
                    print(f"[dryrun] FAIL {tag}: {e}", flush=True)
                path.write_text(json.dumps(res, indent=2))
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}")
        raise SystemExit(1)
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
