"""Content-addressed sweep-cell keys.

A *cell* is one (workflow, scenario, policy/replan config, seed,
backend) simulation whose summary row is immutable given the code: the
engine is deterministic, so the row is a pure function of those inputs
plus the code itself.  :func:`cell_key` hashes all of them —

* the **workflow structural signature** (what the scenario runner's
  ``build_stack`` would unroll: cockpit replicas, load factor,
  deadlines, chain/DAG structure),
* the **scenario token**: the script's structural ``cache_token()``
  (segments, bursts, dropouts, per-mode sensor-rate modulation) *and*
  its ``profile_token()`` (the registered mode transforms, which change
  sampled durations without changing structure),
* the **full policy / replan / workload config** of the spec (every
  semantic ``ScenarioSpec`` field; precompiled portfolios and
  ``mode_defs`` are excluded — they are performance vehicles whose
  content is already covered by the config and the profile token),
* the **seed**, the **backend equivalence class** ("exact" for the
  bit-identical scalar/lockstep engines, "soa" for the distributional
  jax backend), and the **code-contract version**
  (:data:`CONTRACT_VERSION`) — bump it whenever an engine change
  alters row content, and every cached row is invalidated at once.

The key is a sha256 hex digest over a canonical JSON encoding, so it is
stable across processes, hosts, and Python hash randomization — the
property that lets a fleet of workers share one result cache.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
from typing import Dict, Optional

from ..core.benchmark import make_ads_benchmark

__all__ = ["CONTRACT_VERSION", "cell_key", "key_payload", "resolve_backend_class"]

#: bump on any engine/summarize change that alters sweep-row content
#: for identical inputs (see docs/sweeps.md#invalidating-the-cache)
CONTRACT_VERSION = 1

#: ``ScenarioSpec`` fields that determine the row.  ``portfolio`` and
#: ``mode_defs`` are deliberately absent (see module docstring);
#: ``scenario`` and ``seed`` are handled separately.
_CONFIG_FIELDS = (
    "policy", "tiles", "cockpit_replicas", "load_factor", "deadline_s",
    "q", "num_partitions", "drop_policy", "p99_ratio", "dram_utilization",
    "replan", "replan_mode", "forecast_lead_s", "detection_delay_s",
    "route_forecast", "target_miss", "record",
)


def _canon(obj) -> object:
    """Recursively convert ``obj`` to canonical JSON-able form.

    Handles the value types that appear in scenario/mode tokens:
    scalars, tuples/lists, mappings (sorted), and frozen dataclasses
    (tagged with the class name so two types with equal fields do not
    collide).  Anything else is a hard error — silently repr()-ing
    unknown objects would bake memory addresses into cache keys.
    """
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {
            "__dc__": type(obj).__name__,
            **{
                f.name: _canon(getattr(obj, f.name))
                for f in dataclasses.fields(obj)
            },
        }
    if isinstance(obj, (list, tuple)):
        return [_canon(x) for x in obj]
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in sorted(obj.items(), key=lambda kv: str(kv[0]))}
    raise TypeError(
        f"cell_key cannot canonicalize {type(obj).__name__!r} "
        "(extend repro.sweeps.cellkey._canon if this type is semantic)"
    )


@functools.lru_cache(maxsize=64)
def _workflow_signature(
    cockpit_replicas: int, load_factor: float, deadline_s: float
) -> tuple:
    """Structural signature of the workflow ``build_stack`` would
    construct for these spec fields (memoized — the benchmark DAG is
    cheap but not free, and campaigns share one workload)."""
    wf = make_ads_benchmark(
        cockpit_replicas=cockpit_replicas,
        load_factor=load_factor,
        critical_deadline_s=deadline_s,
        cockpit_deadline_s=max(deadline_s, 0.100),
    )
    return wf.structural_signature


def resolve_backend_class(backend: str) -> str:
    """Collapse a requested backend onto its cache equivalence class.

    ``scalar``/``lockstep``/``auto`` all produce bit-identical rows
    (the lockstep engine's CI-gated contract), so their cells share
    cache entries under the class ``"exact"``; the SoA backend is only
    distributionally equivalent and keeps its own class ``"soa"``.
    """
    if backend in ("auto", "scalar", "lockstep", "exact"):
        return "exact"
    if backend == "soa":
        return "soa"
    raise ValueError(f"unknown backend {backend!r}")


def key_payload(
    spec, *, backend: str = "exact",
    contract_version: Optional[int] = None,
) -> Dict[str, object]:
    """The canonical dict :func:`cell_key` hashes (exposed for tests
    and for debugging key mismatches)."""
    scen = spec.scenario
    duration = scen.duration_s if spec.duration_s is None else spec.duration_s
    return {
        "contract": CONTRACT_VERSION if contract_version is None else contract_version,
        "backend": resolve_backend_class(backend),
        "workflow": _canon(_workflow_signature(
            spec.cockpit_replicas, spec.load_factor, spec.deadline_s,
        )),
        "scenario": {
            "structure": _canon(scen.cache_token()),
            "profiles": _canon(scen.profile_token()),
        },
        "config": {f: _canon(getattr(spec, f)) for f in _CONFIG_FIELDS},
        "duration_s": float(duration),
        "seed": int(spec.seed),
    }


def cell_key(
    spec, *, backend: str = "exact",
    contract_version: Optional[int] = None,
) -> str:
    """Content-addressed key of one sweep cell (64 hex chars)."""
    payload = key_payload(
        spec, backend=backend, contract_version=contract_version,
    )
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()
