"""Campaign manifest: durable, resumable record of a sweep campaign.

The manifest is a single JSON document holding the campaign spec
(enough to rebuild every cell deterministically), one record per cell
(content-addressed key, status, cache path, error), and the cache
directory it was run against.  It is the unit of resumption — rerun
the service on a manifest (or on the identical campaign spec) and only
cells whose rows are missing from the cache execute — and the unit of
sharding: ``repro.sweeps.worker`` takes a manifest plus ``--shard
i/k`` and processes its slice.

Statuses: ``pending`` (not attempted), ``cached`` (row served from the
cache without executing), ``done`` (executed this run, row persisted),
``failed`` (executed, raised; ``error`` holds the repr + traceback).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from pathlib import Path
from typing import Dict, List, Optional

__all__ = ["CellRecord", "CampaignManifest", "MANIFEST_VERSION"]

MANIFEST_VERSION = 1

_STATUSES = ("pending", "cached", "done", "failed")


@dataclasses.dataclass
class CellRecord:
    """One sweep cell's durable state."""

    index: int                 # position in the campaign's cell order
    key: str                   # content-addressed cell key (sha256 hex)
    scenario_index: int
    policy: str
    seed: int
    backend: str               # cache equivalence class ("exact"/"soa")
    status: str = "pending"
    cache_path: Optional[str] = None   # relative to the cache root
    error: Optional[str] = None

    def mark(self, status: str, *, cache_path: Optional[str] = None,
             error: Optional[str] = None) -> None:
        if status not in _STATUSES:
            raise ValueError(f"unknown cell status {status!r}")
        self.status = status
        if cache_path is not None:
            self.cache_path = cache_path
        self.error = error

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "CellRecord":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass
class CampaignManifest:
    """The resumable on-disk form of one campaign."""

    campaign: Dict[str, object]        # CampaignSpec.to_dict()
    cells: List[CellRecord]
    cache_dir: Optional[str] = None
    version: int = MANIFEST_VERSION

    # -- queries ----------------------------------------------------------
    def counts(self) -> Dict[str, int]:
        out = {s: 0 for s in _STATUSES}
        for c in self.cells:
            out[c.status] = out.get(c.status, 0) + 1
        return out

    def pending(self) -> List[CellRecord]:
        return [c for c in self.cells if c.status in ("pending", "failed")]

    def failed_keys(self) -> List[str]:
        return [c.key for c in self.cells if c.status == "failed"]

    def by_key(self) -> Dict[str, CellRecord]:
        return {c.key: c for c in self.cells}

    # -- persistence ------------------------------------------------------
    def to_dict(self) -> Dict[str, object]:
        return {
            "version": self.version,
            "campaign": self.campaign,
            "cache_dir": self.cache_dir,
            "counts": self.counts(),
            "cells": [c.to_dict() for c in self.cells],
        }

    def save(self, path) -> Path:
        """Atomic write (temp + rename): an interrupted campaign never
        leaves a half-written manifest behind."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        blob = json.dumps(self.to_dict(), indent=2)
        fd, tmp = tempfile.mkstemp(
            dir=str(path.parent), prefix=f".{path.name}-", suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as fh:
                fh.write(blob)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    @classmethod
    def load(cls, path) -> "CampaignManifest":
        with open(path, "r", encoding="utf-8") as fh:
            d = json.load(fh)
        version = int(d.get("version", 0))
        if version > MANIFEST_VERSION:
            raise ValueError(
                f"manifest version {version} is newer than this code "
                f"({MANIFEST_VERSION}); refusing to guess"
            )
        return cls(
            campaign=dict(d["campaign"]),
            cells=[CellRecord.from_dict(c) for c in d["cells"]],
            cache_dir=d.get("cache_dir"),
            version=version,
        )

    @staticmethod
    def is_manifest(d: Dict[str, object]) -> bool:
        """Heuristic for CLI front-ends accepting either a campaign
        spec or a manifest file."""
        return "cells" in d and "campaign" in d
