"""Work-sharded sweep campaigns: build cells, serve from cache, execute
the rest, aggregate online, record a resumable manifest.

A **campaign** is the declarative form of ``repro.scenarios.sweep``:
``n_scenarios`` Markov-sampled drives x ``policies``, with the same
deterministic seeding (scenario ``i`` uses ``seed * 100003 + i``), the
same per-policy portfolio sharing, and the same backend semantics — so
a campaign executed cold produces row-for-row the list ``sweep()``
returns.  What the campaign adds is durability and scale:

* every cell is **content-addressed** (:mod:`repro.sweeps.cellkey`);
  rows land in an on-disk :class:`~repro.sweeps.cache.ResultCache`,
  so re-running an identical campaign executes zero cells and
  extending one (more seeds, one more policy) executes only the new
  cells;
* a **manifest** (:mod:`repro.sweeps.manifest`) records the campaign
  spec and per-cell status — the resume format ``benchmarks/run.py
  --campaign`` and the weekly extended-sweep CI job consume;
* execution is **pluggable** (:mod:`repro.sweeps.executor`): the local
  spawn pool, or manifest shards across worker subprocesses/hosts;
* aggregation **streams** (:class:`~repro.sweeps.reduce.SweepReducer`)
  so a 100k-drive campaign never needs all rows in memory
  (``keep_rows=False``);
* a crashing cell no longer destroys the sweep: per-cell errors are
  captured, every finished row is persisted to the cache *before* the
  failure re-raises, and the failed cell keys are surfaced in the
  manifest (:class:`SweepFailure`).
"""
from __future__ import annotations

import dataclasses
import traceback
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from .cache import ResultCache
from .cellkey import cell_key, resolve_backend_class
from .executor import ItemFailure, LocalPoolExecutor, SubprocessShardExecutor
from .manifest import CampaignManifest, CellRecord
from .reduce import SweepReducer

__all__ = [
    "CampaignSpec",
    "Cell",
    "CampaignResult",
    "SweepFailure",
    "build_cells",
    "run_campaign",
]


# ---------------------------------------------------------------------------
# campaign spec
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CampaignSpec:
    """Declarative description of one sweep campaign (JSON-able, so a
    manifest can rebuild every cell deterministically)."""

    name: str = "campaign"
    n_scenarios: int = 4
    policies: Tuple[str, ...] = ("ads_tile", "tp_driven")
    #: per-drive scenario length fed to the Markov generator
    scenario_duration_s: float = 2.0
    seed: int = 0
    replan: bool = True
    #: requested engine: "auto"/"scalar"/"lockstep" (bit-identical rows,
    #: cache class "exact") or "soa" (distributional, own cache class)
    backend: str = "auto"
    #: None = the bundled default generator
    generator: Optional[object] = None          # MarkovScenarioGenerator
    #: extra ScenarioSpec fields (tiles, record, target_miss, ...)
    spec_kw: Dict[str, object] = dataclasses.field(default_factory=dict)
    #: mode definitions to register before building cells; None = the
    #: registry's current modes for the generator's mode set.  Filled
    #: on serialization so shard workers in fresh processes see custom
    #: modes.
    mode_defs: Optional[Dict[str, object]] = None

    def __post_init__(self) -> None:
        self.policies = tuple(self.policies)
        if self.n_scenarios < 1:
            raise ValueError("n_scenarios must be >= 1")
        if not self.policies:
            raise ValueError("campaign needs at least one policy")

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, object]:
        from ..scenarios.modes import get_mode
        from ..scenarios.script import default_generator

        gen = self.generator or default_generator()
        mode_defs = self.mode_defs or {
            m: get_mode(m) for m in sorted(gen.transitions)
        }
        return {
            "name": self.name,
            "n_scenarios": self.n_scenarios,
            "policies": list(self.policies),
            "scenario_duration_s": self.scenario_duration_s,
            "seed": self.seed,
            "replan": self.replan,
            "backend": self.backend,
            "generator": (
                None if self.generator is None
                else dataclasses.asdict(self.generator)
            ),
            "spec_kw": dict(self.spec_kw),
            "modes": {
                m: dataclasses.asdict(d) for m, d in sorted(mode_defs.items())
            },
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "CampaignSpec":
        from ..scenarios.modes import DrivingMode
        from ..scenarios.script import MarkovScenarioGenerator

        gen = None
        g = d.get("generator")
        if g is not None:
            g = dict(g)  # type: ignore[arg-type]
            g["dropout_sensors"] = tuple(g.get("dropout_sensors", ()))
            gen = MarkovScenarioGenerator(**g)
        mode_defs = None
        if d.get("modes"):
            mode_defs = {
                m: DrivingMode(**md)  # type: ignore[arg-type]
                for m, md in d["modes"].items()  # type: ignore[union-attr]
            }
        return cls(
            name=str(d.get("name", "campaign")),
            n_scenarios=int(d["n_scenarios"]),  # type: ignore[arg-type]
            policies=tuple(d.get("policies", ("ads_tile", "tp_driven"))),  # type: ignore[arg-type]
            scenario_duration_s=float(d.get("scenario_duration_s", 2.0)),  # type: ignore[arg-type]
            seed=int(d.get("seed", 0)),  # type: ignore[arg-type]
            replan=bool(d.get("replan", True)),
            backend=str(d.get("backend", "auto")),
            generator=gen,
            spec_kw=dict(d.get("spec_kw", {})),  # type: ignore[arg-type]
            mode_defs=mode_defs,
        )


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Cell:
    """One (scenario, policy, seed) unit of campaign work."""

    index: int
    scenario_index: int
    spec: object               # ScenarioSpec
    key: str
    backend_class: str         # "exact" | "soa"


def build_cells(campaign: CampaignSpec) -> List[Cell]:
    """Deterministically expand a campaign into its cells.

    Mirrors ``repro.scenarios.sweep`` exactly: scenario ``i`` is
    sampled with seed ``campaign.seed * 100003 + i`` and simulated with
    that seed for every policy, so policy comparisons stay paired.
    """
    from ..scenarios import runner as _runner
    from ..scenarios.modes import get_mode, register_mode
    from ..scenarios.script import default_generator

    gen = campaign.generator or default_generator()
    all_modes = sorted(gen.transitions)
    if campaign.mode_defs:
        # a campaign deserialized in a fresh process carries its mode
        # definitions along (idempotent re-registration, like
        # ScenarioSpec.mode_defs in pool workers)
        for mode in campaign.mode_defs.values():
            register_mode(mode, overwrite=True)
    mode_defs = {m: get_mode(m) for m in all_modes}

    cells: List[Cell] = []
    for i in range(campaign.n_scenarios):
        s_i = campaign.seed * 100003 + i
        script = gen.sample(campaign.scenario_duration_s, seed=s_i)
        for pol in campaign.policies:
            spec = _runner.ScenarioSpec(
                scenario=script, policy=pol, replan=campaign.replan,
                seed=s_i, mode_defs=mode_defs, **campaign.spec_kw,
            )
            bclass = _cell_backend_class(campaign.backend, spec)
            cells.append(Cell(
                index=len(cells), scenario_index=i, spec=spec,
                key=cell_key(spec, backend=bclass), backend_class=bclass,
            ))
    return cells


def _cell_backend_class(requested: str, spec) -> str:
    """The cache equivalence class a cell will actually run under —
    the single place the per-spec SoA fallback decision is made for
    campaigns (the runner's ``run()`` owns it for direct calls)."""
    if requested == "soa":
        from ..scenarios.runner import soa_usable

        ok, _why = soa_usable(spec)
        return "soa" if ok else "exact"
    return resolve_backend_class(requested)


def _attach_portfolios(cells: Sequence[Cell], campaign: CampaignSpec) -> None:
    """One schedule portfolio per policy, shared by every cell of that
    policy (the ``sweep()`` optimization: compile once in the parent
    instead of once per worker run)."""
    from ..scenarios.runner import compile_portfolio
    from ..scenarios.script import default_generator

    gen = campaign.generator or default_generator()
    all_modes = sorted(gen.transitions)
    portfolios: Dict[str, object] = {}
    for cell in cells:
        pol = cell.spec.policy
        if pol not in portfolios:
            portfolios[pol] = compile_portfolio(cell.spec, all_modes)
        cell.spec = dataclasses.replace(cell.spec, portfolio=portfolios[pol])


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _GroupTask:
    """One executor work item: every pending cell of one scenario
    (paired policies share the scenario's sampled trace)."""

    specs: List[object]
    cells: List[Tuple[int, str]]       # (cell index, cell key)
    backend: str                       # campaign's requested backend


def _run_cell_group(task: _GroupTask) -> List[tuple]:
    """Run one scenario's pending cells; per-cell error capture.

    Returns ``("ok", index, key, row)`` / ``("err", index, key, error)``
    tuples.  A group-level failure (e.g. trace sampling) retries each
    spec alone so one broken cell cannot take its siblings' results
    down with it.
    """
    from ..scenarios import runner as _runner

    backend = "lockstep" if task.backend == "auto" else task.backend
    try:
        rows = _runner._run_group(task.specs, backend=backend)
        return [
            ("ok", idx, key, row)
            for (idx, key), row in zip(task.cells, rows)
        ]
    except Exception:
        out: List[tuple] = []
        for (idx, key), spec in zip(task.cells, task.specs):
            try:
                row = _runner._run_group([spec], backend=backend)[0]
                out.append(("ok", idx, key, row))
            except Exception as exc:  # noqa: BLE001 - captured per cell
                out.append((
                    "err", idx, key,
                    f"{exc!r}\n{traceback.format_exc()}",
                ))
        return out


class SweepFailure(RuntimeError):
    """Raised when cells failed and ``allow_failures`` is off.  By the
    time this surfaces, every *finished* cell's row is already
    persisted in the cache and the manifest lists the failed keys —
    rerunning the same campaign retries only the failures."""

    def __init__(self, failed_keys: Sequence[str], result: "CampaignResult",
                 detail: str = "") -> None:
        self.failed_keys = list(failed_keys)
        self.result = result
        msg = (
            f"{len(self.failed_keys)} sweep cell(s) failed "
            f"(completed rows are cached; failed keys in the manifest)"
        )
        if detail:
            msg += f": {detail.splitlines()[0]}"
        super().__init__(msg)


@dataclasses.dataclass
class CampaignResult:
    """Outcome of :func:`run_campaign`."""

    campaign: CampaignSpec
    manifest: CampaignManifest
    #: successful rows in cell order (``None`` when ``keep_rows=False``)
    rows: Optional[List[Dict[str, object]]]
    #: streaming per-policy aggregate (:meth:`SweepReducer.result`)
    aggregate: Dict[str, Dict[str, object]]
    n_cells: int
    n_cached: int
    n_executed: int
    n_failed: int
    failed_keys: List[str]


def _coerce_campaign(
    campaign: Union[CampaignSpec, Mapping, str, Path],
) -> Tuple[CampaignSpec, Optional[str]]:
    """Accept a spec object, a spec dict, a campaign-spec JSON path, or
    a manifest JSON path; return ``(spec, manifest_cache_dir)``."""
    if isinstance(campaign, CampaignSpec):
        return campaign, None
    if isinstance(campaign, (str, Path)):
        import json

        with open(campaign, "r", encoding="utf-8") as fh:
            campaign = json.load(fh)
    if not isinstance(campaign, Mapping):
        raise TypeError(f"not a campaign: {campaign!r}")
    if CampaignManifest.is_manifest(dict(campaign)):
        return (
            CampaignSpec.from_dict(campaign["campaign"]),  # type: ignore[index]
            campaign.get("cache_dir"),  # type: ignore[union-attr]
        )
    return CampaignSpec.from_dict(campaign), None


def run_campaign(
    campaign: Union[CampaignSpec, Mapping, str, Path],
    *,
    cache_dir: Union[str, Path, None] = None,
    manifest_path: Union[str, Path, None] = None,
    executor: Union[LocalPoolExecutor, SubprocessShardExecutor, None] = None,
    jobs: Optional[int] = None,
    reducer: Optional[SweepReducer] = None,
    keep_rows: bool = True,
    allow_failures: bool = False,
) -> CampaignResult:
    """Run (or resume) a campaign against a content-addressed cache.

    ``campaign`` may be a :class:`CampaignSpec`, a campaign-spec dict /
    JSON path, or a previously saved **manifest** path — resumption is
    simply re-running: cells whose rows are in the cache are served
    without executing, the rest run, and the resumed result is
    row-for-row identical to an uninterrupted run (cells are
    deterministic and content-addressed).

    ``executor`` defaults to :class:`LocalPoolExecutor(jobs)`; pass a
    :class:`SubprocessShardExecutor` to fan the manifest out across
    worker invocations (requires ``manifest_path``).  ``keep_rows=False``
    streams every row straight into the reducer and returns
    ``rows=None`` — the O(1)-memory shape for very large campaigns.
    """
    spec_obj, manifest_cache = _coerce_campaign(campaign)
    if cache_dir is None:
        cache_dir = manifest_cache
    if cache_dir is None:
        raise ValueError(
            "cache_dir is required (or resume from a manifest that "
            "records one)"
        )
    cache = ResultCache(cache_dir)
    reducer = reducer if reducer is not None else SweepReducer()

    cells = build_cells(spec_obj)
    records = [
        CellRecord(
            index=c.index, key=c.key, scenario_index=c.scenario_index,
            policy=str(c.spec.policy), seed=int(c.spec.seed),
            backend=c.backend_class,
        )
        for c in cells
    ]
    manifest = CampaignManifest(
        campaign=spec_obj.to_dict(), cells=records,
        cache_dir=str(cache.root),
    )

    rows: List[Optional[Dict[str, object]]] = [None] * len(cells)
    n_cached = 0
    for c, recd in zip(cells, records):
        row = cache.get(c.key)
        if row is not None:
            n_cached += 1
            recd.mark("cached", cache_path=cache.relative_path(c.key))
            if keep_rows:
                rows[c.index] = row
            else:
                reducer.update(row)
    if manifest_path is not None:
        manifest.save(manifest_path)

    missing = [c for c in cells if records[c.index].status == "pending"]
    n_executed = 0
    if missing:
        if isinstance(executor, SubprocessShardExecutor):
            if manifest_path is None:
                raise ValueError(
                    "SubprocessShardExecutor needs manifest_path (the "
                    "manifest is the work-distribution medium)"
                )
            n_executed = _execute_sharded(
                executor, manifest, manifest_path, cache, missing,
                records, rows, reducer, keep_rows,
            )
        else:
            n_executed = _execute_local(
                executor or LocalPoolExecutor(jobs), spec_obj, cache,
                missing, records, rows, reducer, keep_rows,
                manifest, manifest_path,
            )
    if keep_rows:
        for row in rows:
            if row is not None:
                reducer.update(row)

    if manifest_path is not None:
        manifest.save(manifest_path)
    failed = manifest.failed_keys()
    result = CampaignResult(
        campaign=spec_obj,
        manifest=manifest,
        rows=(
            [r for r in rows if r is not None] if keep_rows else None
        ),
        aggregate=reducer.result(),
        n_cells=len(cells),
        n_cached=n_cached,
        n_executed=n_executed,
        n_failed=len(failed),
        failed_keys=failed,
    )
    if failed and not allow_failures:
        first = next(
            (r.error for r in records if r.status == "failed" and r.error),
            "",
        )
        raise SweepFailure(failed, result, detail=first or "")
    return result


def _execute_local(
    executor: LocalPoolExecutor,
    spec_obj: CampaignSpec,
    cache: ResultCache,
    missing: Sequence[Cell],
    records: Sequence[CellRecord],
    rows: List[Optional[Dict[str, object]]],
    reducer: SweepReducer,
    keep_rows: bool,
    manifest: CampaignManifest,
    manifest_path,
) -> int:
    _attach_portfolios(missing, spec_obj)
    groups: Dict[int, List[Cell]] = {}
    for c in missing:
        groups.setdefault(c.scenario_index, []).append(c)
    tasks = [
        _GroupTask(
            specs=[c.spec for c in cs],
            cells=[(c.index, c.key) for c in cs],
            backend=spec_obj.backend,
        )
        for _si, cs in sorted(groups.items())
    ]
    n_executed = 0
    for i, outcome in executor.imap(_run_cell_group, tasks):
        task = tasks[i]
        if isinstance(outcome, ItemFailure):
            for idx, _key in task.cells:
                records[idx].mark("failed", error=(
                    f"{outcome.error}\n{outcome.traceback}"
                ))
        else:
            for entry in outcome:
                if entry[0] == "ok":
                    _tag, idx, key, row = entry
                    cache.put(key, row)
                    records[idx].mark(
                        "done", cache_path=cache.relative_path(key),
                    )
                    n_executed += 1
                    if keep_rows:
                        rows[idx] = row
                    else:
                        reducer.update(row)
                else:
                    _tag, idx, _key, err = entry
                    records[idx].mark("failed", error=err)
        if manifest_path is not None:
            # checkpoint after every group: an interruption here loses
            # at most the in-flight groups, never finished cells
            manifest.save(manifest_path)
    return n_executed


def _execute_sharded(
    executor: SubprocessShardExecutor,
    manifest: CampaignManifest,
    manifest_path,
    cache: ResultCache,
    missing: Sequence[Cell],
    records: Sequence[CellRecord],
    rows: List[Optional[Dict[str, object]]],
    reducer: SweepReducer,
    keep_rows: bool,
) -> int:
    shard_results = executor.run_manifest(manifest_path, cache.root)
    reported: Dict[str, Optional[str]] = {}
    for sr in shard_results:
        for cd in sr.cells:
            reported[str(cd["key"])] = cd.get("error")
    n_executed = 0
    for c in missing:
        row = cache.get(c.key)
        if row is not None:
            n_executed += 1
            records[c.index].mark(
                "done", cache_path=cache.relative_path(c.key),
            )
            if keep_rows:
                rows[c.index] = row
            else:
                reducer.update(row)
        else:
            err = reported.get(c.key) or (
                "cell not executed by any shard (worker crash? see "
                "shard stderr)"
            )
            records[c.index].mark("failed", error=err)
    return n_executed
