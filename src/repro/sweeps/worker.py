"""Manifest shard worker: ``python -m repro.sweeps.worker``.

One invocation processes one shard of a campaign manifest: it rebuilds
the campaign's cells deterministically from the manifest's embedded
spec, keeps the cell *groups* whose ``scenario_index % num_shards ==
shard`` (groups stay whole so the shared-trace policy pairing is
preserved), skips anything already in the shared result cache, runs
the rest, and writes the rows into the cache.  Workers coordinate
only through the manifest (read-only) and the cache (atomic writes),
so any number of them can run concurrently on one host or — with the
cache on a shared filesystem — across hosts.

The ``--report`` JSON is for the parent
(:class:`~repro.sweeps.executor.SubprocessShardExecutor`) to merge
per-cell outcomes back into the manifest; the cache itself is the
source of truth for rows.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

from .cache import ResultCache
from .executor import ItemFailure, LocalPoolExecutor
from .manifest import CampaignManifest

__all__ = ["run_shard", "main"]


def run_shard(
    manifest_path,
    cache_dir,
    shard: int = 0,
    num_shards: int = 1,
    jobs: Optional[int] = 1,
    max_groups: Optional[int] = None,
) -> Dict[str, object]:
    """Execute this shard's pending cells; return the shard report.

    ``max_groups`` bounds how many scenario groups run (used by tests
    to simulate an interrupted campaign: run a few groups, "crash",
    then resume from the manifest).
    """
    from .service import (
        CampaignSpec,
        _GroupTask,
        _attach_portfolios,
        _run_cell_group,
        build_cells,
    )

    if not 0 <= shard < num_shards:
        raise ValueError(f"shard {shard} outside 0..{num_shards - 1}")
    manifest = CampaignManifest.load(manifest_path)
    campaign = CampaignSpec.from_dict(manifest.campaign)
    cache = ResultCache(cache_dir)

    cells = build_cells(campaign)
    mine = [
        c for c in cells
        if c.scenario_index % num_shards == shard
        and cache.get(c.key) is None        # full read: corrupt == missing
    ]
    groups: Dict[int, list] = {}
    for c in mine:
        groups.setdefault(c.scenario_index, []).append(c)
    picked = sorted(groups.items())
    if max_groups is not None:
        picked = picked[:max_groups]
    if picked:
        flat = [c for _si, cs in picked for c in cs]
        _attach_portfolios(flat, campaign)
    tasks = [
        _GroupTask(
            specs=[c.spec for c in cs],
            cells=[(c.index, c.key) for c in cs],
            backend=campaign.backend,
        )
        for _si, cs in picked
    ]

    cell_reports: List[Dict[str, object]] = []
    n_executed = n_failed = 0
    for i, outcome in LocalPoolExecutor(jobs).imap(_run_cell_group, tasks):
        if isinstance(outcome, ItemFailure):
            n_failed += len(tasks[i].cells)
            for idx, key in tasks[i].cells:
                cell_reports.append({
                    "index": idx, "key": key, "status": "failed",
                    "error": f"{outcome.error}\n{outcome.traceback}",
                })
            continue
        for entry in outcome:
            if entry[0] == "ok":
                _tag, idx, key, row = entry
                cache.put(key, row)
                n_executed += 1
                cell_reports.append({
                    "index": idx, "key": key, "status": "done",
                    "error": None,
                })
            else:
                _tag, idx, key, err = entry
                n_failed += 1
                cell_reports.append({
                    "index": idx, "key": key, "status": "failed",
                    "error": err,
                })
    return {
        "shard": shard,
        "num_shards": num_shards,
        "n_cells": len(mine),
        "n_executed": n_executed,
        "n_failed": n_failed,
        "cells": cell_reports,
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweeps.worker",
        description="run one shard of a sweep-campaign manifest",
    )
    ap.add_argument("--manifest", required=True)
    ap.add_argument("--cache-dir", required=True)
    ap.add_argument("--shard", type=int, default=0)
    ap.add_argument("--num-shards", type=int, default=1)
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument(
        "--report", default=None,
        help="write the shard report JSON here (default: stdout)",
    )
    args = ap.parse_args(argv)
    report = run_shard(
        args.manifest, args.cache_dir,
        shard=args.shard, num_shards=args.num_shards, jobs=args.jobs,
    )
    blob = json.dumps(report, indent=2)
    if args.report:
        with open(args.report, "w", encoding="utf-8") as fh:
            fh.write(blob)
    else:
        print(blob)
    # per-cell failures are data, not a worker crash: the parent reads
    # them from the report; a nonzero exit is reserved for the worker
    # itself breaking
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    sys.exit(main())
