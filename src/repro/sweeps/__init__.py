"""Work-sharded sweep service: content-addressed cell cache, resumable
campaign manifests, pluggable executors, streaming aggregation.

Quick tour (details in ``docs/sweeps.md``):

* :func:`~repro.sweeps.cellkey.cell_key` — content-addressed key of one
  sweep cell (workflow signature + scenario tokens + full config + seed
  + backend class + :data:`~repro.sweeps.cellkey.CONTRACT_VERSION`).
* :class:`~repro.sweeps.cache.ResultCache` — on-disk row store keyed by
  cell keys; repeated sweeps only execute new cells.
* :class:`~repro.sweeps.reduce.SweepReducer` — online per-policy
  aggregation (``update(row)`` / ``result()``);
  ``repro.scenarios.aggregate_sweep`` is now a thin batch wrapper.
* :class:`~repro.sweeps.executor.LocalPoolExecutor` /
  :class:`~repro.sweeps.executor.SubprocessShardExecutor` — how cells
  run: today's spawn pool, or manifest shards across worker processes.
* :class:`~repro.sweeps.manifest.CampaignManifest` — the durable,
  resumable record one campaign leaves behind.
* :func:`~repro.sweeps.service.run_campaign` /
  :class:`~repro.sweeps.service.CampaignSpec` — the service tying it
  together (lazily imported: it pulls in the scenario runner).
"""
from __future__ import annotations

from .cache import ResultCache
from .cellkey import CONTRACT_VERSION, cell_key, key_payload, resolve_backend_class
from .executor import (
    ItemFailure,
    LocalPoolExecutor,
    ShardResult,
    SubprocessShardExecutor,
)
from .manifest import MANIFEST_VERSION, CampaignManifest, CellRecord
from .reduce import SweepReducer
from .rows import SweepRow

__all__ = [
    "CONTRACT_VERSION",
    "MANIFEST_VERSION",
    "CampaignManifest",
    "CampaignResult",
    "CampaignSpec",
    "Cell",
    "CellRecord",
    "ItemFailure",
    "LocalPoolExecutor",
    "ResultCache",
    "ShardResult",
    "SubprocessShardExecutor",
    "SweepFailure",
    "SweepReducer",
    "SweepRow",
    "build_cells",
    "cell_key",
    "key_payload",
    "resolve_backend_class",
    "run_campaign",
    "run_shard",
]

#: symbols resolved lazily (PEP 562): ``service``/``worker`` import the
#: scenario runner, which itself imports this package for SweepRow /
#: SweepReducer — eager imports here would cycle.
_LAZY = {
    "CampaignResult": "service",
    "CampaignSpec": "service",
    "Cell": "service",
    "SweepFailure": "service",
    "build_cells": "service",
    "run_campaign": "service",
    "run_shard": "worker",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{mod}", __name__), name)


def __dir__():
    return sorted(__all__)
