"""Pluggable sweep-cell executors.

The sweep service separates *what* to run (a campaign's cell groups)
from *how* to run it:

* :class:`LocalPoolExecutor` — today's single-host spawn pool
  (``parallel_map`` semantics: order-preserving, ``spawn`` start
  method, degrade-to-serial inside daemonic workers), upgraded with
  per-item **error capture**: one crashing cell no longer aborts the
  whole sweep and discards every completed result.  The scenario
  runner's ``parallel_map`` is now a thin wrapper over this class.
* :class:`SubprocessShardExecutor` — shards a campaign *manifest*
  across independent ``python -m repro.sweeps.worker`` invocations
  that coordinate only through the manifest and the shared result
  cache.  On one host it is a process-isolation harness; pointed at a
  shared filesystem it is the multi-host shape (one invocation per
  host, ``--shard i --num-shards k``).
"""
from __future__ import annotations

import dataclasses
import json
import multiprocessing
import os
import subprocess
import sys
import tempfile
import traceback
from pathlib import Path
from typing import Callable, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "ItemFailure",
    "LocalPoolExecutor",
    "SubprocessShardExecutor",
    "ShardResult",
]


@dataclasses.dataclass
class ItemFailure:
    """One failed work item: the exception (when it survived pickling
    back from the worker), its repr, and the worker-side traceback."""

    index: int
    error: str
    traceback: str
    exception: Optional[BaseException] = None

    def reraise(self) -> "NoReturn":  # type: ignore[name-defined]  # noqa: F821
        if self.exception is not None:
            raise self.exception
        raise RuntimeError(
            f"sweep work item {self.index} failed: {self.error}\n{self.traceback}"
        )


class _Capture:
    """Picklable wrapper turning ``fn(item)`` into a tagged outcome
    tuple, so worker exceptions travel back as data."""

    __slots__ = ("fn",)

    def __init__(self, fn: Callable) -> None:
        self.fn = fn

    def __call__(self, item):
        try:
            return ("ok", self.fn(item))
        except BaseException as exc:  # noqa: BLE001 - captured, not hidden
            tb = traceback.format_exc()
            try:  # exceptions normally pickle; fall back to repr-only
                import pickle

                pickle.dumps(exc)
                payload = exc
            except Exception:
                payload = None
            return ("err", payload, repr(exc), tb)


def _resolve_jobs(jobs: Optional[int], n_items: int) -> int:
    if jobs is None:
        jobs = os.cpu_count() or 1
    jobs = min(jobs, n_items)
    if multiprocessing.current_process().daemon:
        # already inside a pool worker (e.g. a sweep launched by
        # ``benchmarks.run --jobs``): daemonic processes cannot spawn
        # children, so degrade to the in-process loop
        jobs = 1
    return jobs


class LocalPoolExecutor:
    """Order-preserving process-pool executor (``spawn`` start method;
    ``fn`` and items must be picklable).  ``jobs`` <= 1 or a single
    item degrades to a plain in-process loop."""

    name = "local-pool"

    def __init__(self, jobs: Optional[int] = None) -> None:
        self.jobs = jobs

    def imap(self, fn: Callable, items: Sequence) -> Iterator[Tuple[int, object]]:
        """Yield ``(index, outcome)`` in item order as results finish;
        ``outcome`` is the return value or an :class:`ItemFailure`.
        Results stream, so a caller can persist/aggregate completed
        items even if a later one fails."""
        items = list(items)
        jobs = _resolve_jobs(self.jobs, len(items))
        capture = _Capture(fn)
        if jobs <= 1 or len(items) <= 1:
            for i, item in enumerate(items):
                yield i, self._decode(i, capture(item))
            return
        ctx = multiprocessing.get_context("spawn")
        with ctx.Pool(processes=jobs) as pool:
            for i, tagged in enumerate(pool.imap(capture, items)):
                yield i, self._decode(i, tagged)

    @staticmethod
    def _decode(index: int, tagged) -> object:
        if tagged[0] == "ok":
            return tagged[1]
        _tag, exc, err, tb = tagged
        return ItemFailure(index=index, error=err, traceback=tb, exception=exc)

    def map(
        self, fn: Callable, items: Sequence, *, return_errors: bool = False
    ) -> List:
        """``[fn(x) for x in items]`` over the pool.  With
        ``return_errors`` failures come back as :class:`ItemFailure`
        entries in place; without it the first failure re-raises (the
        legacy ``parallel_map`` contract) — but only after the full
        pass, so siblings are not cancelled mid-flight."""
        out = [res for _i, res in self.imap(fn, items)]
        if not return_errors:
            for res in out:
                if isinstance(res, ItemFailure):
                    res.reraise()
        return out


# ---------------------------------------------------------------------------
# manifest-sharding executor (multi-host shape)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ShardResult:
    """Outcome of one ``repro.sweeps.worker`` invocation."""

    shard: int
    returncode: int
    cells: List[dict]          # [{"key", "index", "status", "error"}, ...]
    stderr: str = ""


class SubprocessShardExecutor:
    """Runs a campaign manifest as ``num_shards`` independent worker
    subprocesses (``python -m repro.sweeps.worker``), each owning the
    pending cell groups whose scenario index hashes to its shard.

    Workers never talk to each other: they read the manifest, write
    result rows into the shared content-addressed cache, and emit a
    shard report the parent merges back into the manifest — exactly
    the coordination model that works when "subprocess" becomes "ssh
    to another host" (shared cache directory, one shard id per host).
    """

    name = "subprocess-shard"

    def __init__(
        self,
        num_shards: int = 2,
        jobs_per_shard: int = 1,
        python: Optional[str] = None,
    ) -> None:
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.num_shards = num_shards
        self.jobs_per_shard = jobs_per_shard
        self.python = python or sys.executable

    def run_manifest(
        self, manifest_path, cache_dir, *, timeout: Optional[float] = None
    ) -> List[ShardResult]:
        manifest_path = Path(manifest_path)
        results: List[ShardResult] = []
        procs = []
        with tempfile.TemporaryDirectory(prefix="sweep-shards-") as td:
            for shard in range(self.num_shards):
                report = Path(td) / f"shard-{shard}.json"
                cmd = [
                    self.python, "-m", "repro.sweeps.worker",
                    "--manifest", str(manifest_path),
                    "--cache-dir", str(cache_dir),
                    "--shard", str(shard),
                    "--num-shards", str(self.num_shards),
                    "--jobs", str(self.jobs_per_shard),
                    "--report", str(report),
                ]
                procs.append((shard, report, subprocess.Popen(
                    cmd, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE,
                    text=True,
                )))
            for shard, report, proc in procs:
                _out, err = proc.communicate(timeout=timeout)
                cells: List[dict] = []
                if report.exists():
                    try:
                        cells = json.loads(report.read_text())["cells"]
                    except (ValueError, KeyError):
                        cells = []
                results.append(ShardResult(
                    shard=shard, returncode=proc.returncode,
                    cells=cells, stderr=err or "",
                ))
        return results
