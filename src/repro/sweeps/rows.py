"""Typed sweep rows.

:class:`SweepRow` is the typed replacement for the ad-hoc dict that
``repro.scenarios.runner.summarize`` used to build inline.  The dict
shape is load-bearing — committed benchmark JSON files, the cache files
under a sweep campaign's result store, and ``benchmarks.make_tables``
all consume it — so :meth:`SweepRow.to_dict` reproduces it
byte-for-byte: same keys, same order, same value types.  The dataclass
exists so new code (the sweep service, reducers, tests) gets attribute
access and a stable schema instead of string indexing.

This module is deliberately dependency-light (no imports from
``repro.scenarios``): it is imported *by* the scenario runner.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional

__all__ = ["SweepRow"]


@dataclasses.dataclass
class SweepRow:
    """One (scenario, policy, seed) cell of a Monte-Carlo sweep.

    Field order mirrors the historical ``summarize()`` dict exactly;
    :meth:`to_dict` relies on it.
    """

    scenario: str
    script: str
    policy: str
    replan: bool
    replan_mode: str
    seed: int
    forecast: Optional[Dict[str, object]]
    violation_rate: float
    task_miss_rate: float
    effective_frac: float
    realloc_frac: float
    n_realloc: int
    n_mode_switches: int
    tiles_used: int
    tiles_reserved_mean: float
    target_miss: Optional[float]
    #: deadline-miss decomposition (recorded runs only, else None)
    attribution: Optional[Dict[str, object]]
    per_mode: Dict[str, Dict[str, object]]

    @classmethod
    def from_report(cls, spec, report) -> "SweepRow":
        """Flatten one run into a row.

        ``spec`` is any object with the scenario-runner spec fields
        (``scenario``, ``policy``, ``replan``, ``replan_mode``,
        ``seed``, ``target_miss``); ``report`` is a
        :class:`~repro.core.sim.SimReport`.
        """
        fc = report.forecast
        return cls(
            scenario=spec.scenario.name,
            script=spec.scenario.to_string(),
            policy=spec.policy,
            replan=spec.replan,
            replan_mode=spec.replan_mode,
            seed=spec.seed,
            forecast=None if fc is None else {
                "n_forecasts": fc.n_forecasts,
                "n_preswaps": fc.n_preswaps,
                "n_blends": fc.n_blends,
                "n_hits": fc.n_hits,
                "n_misses": fc.n_misses,
                "n_reverts": fc.n_reverts,
                "hit_rate": fc.hit_rate,
                "prestage_stall_s": fc.prestage_stall_s,
            },
            violation_rate=report.violation_rate,
            task_miss_rate=report.task_miss_rate,
            effective_frac=report.effective_frac,
            realloc_frac=report.realloc_frac,
            n_realloc=report.n_realloc,
            n_mode_switches=report.n_mode_switches,
            tiles_used=report.tiles_used,
            tiles_reserved_mean=report.tiles_reserved_mean,
            target_miss=spec.target_miss,
            attribution=report.attribution,
            per_mode={
                m: {
                    "span_s": s.span_s,
                    "n_completed": s.n_completed,
                    "n_violations": s.n_violations,
                    "violation_rate": s.violation_rate,
                    # None rather than NaN: NaN breaks row equality and JSON
                    "p99_s": None if math.isnan(s.p99_s) else s.p99_s,
                    "effective_frac": s.effective_frac,
                    "realloc_frac": s.realloc_frac,
                }
                for m, s in report.mode_stats.items()
            },
        )

    def to_dict(self) -> Dict[str, object]:
        """The legacy ``summarize()`` dict, byte-for-byte (fresh
        containers, so callers may mutate the result freely)."""
        out: Dict[str, object] = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "per_mode":
                v = {m: dict(st) for m, st in v.items()}
            elif f.name in ("forecast", "attribution") and v is not None:
                v = dict(v)
            out[f.name] = v
        return out

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "SweepRow":
        """Inverse of :meth:`to_dict` (also accepts cache-file JSON)."""
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})
