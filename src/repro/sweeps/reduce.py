"""Streaming sweep aggregation.

:class:`SweepReducer` is the online form of the historical batch
``aggregate_sweep``: feed it rows one at a time (``update``) and ask
for the per-policy aggregate at any point (``result``).  State is O(
policies x modes), independent of the number of rows, so a 100k-drive
campaign can aggregate while it streams out of the executor instead of
materializing every row first.  The batch function
``repro.scenarios.aggregate_sweep`` is now a thin wrapper over this
class, so the two are equal by construction.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping

__all__ = ["SweepReducer"]


class _PolicyAccumulator:
    """Running sums for one policy."""

    __slots__ = (
        "n", "violation_sum", "miss_sum", "realloc_sum", "tiles_used_max",
        "per_mode", "att_n", "att_late", "att_dropped", "att_degraded",
        "att_lateness", "att_components",
    )

    def __init__(self) -> None:
        self.n = 0
        self.violation_sum = 0.0
        self.miss_sum = 0.0
        self.realloc_sum = 0.0
        self.tiles_used_max = 0
        # mode -> [viol_sum, viol_n, p99_sum, p99_n, realloc_sum, realloc_n]
        self.per_mode: Dict[str, List[float]] = {}
        self.att_n = 0
        self.att_late = 0
        self.att_dropped = 0
        self.att_degraded = 0
        self.att_lateness = 0.0
        self.att_components = {
            "queueing": 0.0, "realloc_stall": 0.0,
            "restagger": 0.0, "duration_tail": 0.0,
        }


def _as_mapping(row) -> Mapping[str, object]:
    if isinstance(row, Mapping):
        return row
    to_dict = getattr(row, "to_dict", None)  # SweepRow
    if callable(to_dict):
        return to_dict()
    raise TypeError(f"not a sweep row: {row!r}")


class SweepReducer:
    """Online reducer over sweep rows (dicts or :class:`SweepRow`\\ s).

    ``result()`` returns the same ``{policy: {n, violation_rate,
    task_miss_rate, realloc_frac, tiles_used, per_mode, [attribution]}}``
    mapping as the batch ``aggregate_sweep`` — policies and modes
    sorted, attribution present only when recorded rows were seen.
    ``result()`` does not consume the reducer; updates may continue
    afterwards.
    """

    def __init__(self) -> None:
        self._by_pol: Dict[str, _PolicyAccumulator] = {}
        self.n_rows = 0

    def update(self, row) -> None:
        r = _as_mapping(row)
        acc = self._by_pol.setdefault(str(r["policy"]), _PolicyAccumulator())
        acc.n += 1
        self.n_rows += 1
        acc.violation_sum += float(r["violation_rate"])  # type: ignore[arg-type]
        acc.miss_sum += float(r["task_miss_rate"])  # type: ignore[arg-type]
        acc.realloc_sum += float(r["realloc_frac"])  # type: ignore[arg-type]
        acc.tiles_used_max = max(acc.tiles_used_max, int(r.get("tiles_used", 0)))  # type: ignore[arg-type]
        for m, st in r["per_mode"].items():  # type: ignore[union-attr]
            b = acc.per_mode.setdefault(m, [0.0, 0, 0.0, 0, 0.0, 0])
            b[0] += float(st["violation_rate"])
            b[1] += 1
            if st["p99_s"] is not None:
                b[2] += float(st["p99_s"])
                b[3] += 1
            b[4] += float(st["realloc_frac"])
            b[5] += 1
        a = r.get("attribution")
        if a is not None:
            acc.att_n += 1
            acc.att_late += int(a["n_late"])  # type: ignore[index]
            acc.att_dropped += int(a["n_dropped"])  # type: ignore[index]
            acc.att_degraded += int(a["n_degraded"])  # type: ignore[index]
            acc.att_lateness += float(a["lateness_s"])  # type: ignore[index]
            for k in acc.att_components:
                acc.att_components[k] += float(a["components_s"][k])  # type: ignore[index]

    def update_many(self, rows: Iterable) -> "SweepReducer":
        for r in rows:
            self.update(r)
        return self

    def result(self) -> Dict[str, Dict[str, object]]:
        out: Dict[str, Dict[str, object]] = {}
        for pol, acc in sorted(self._by_pol.items()):
            n = acc.n
            out[pol] = {
                "n": n,
                "violation_rate": acc.violation_sum / n,
                "task_miss_rate": acc.miss_sum / n,
                "realloc_frac": acc.realloc_sum / n,
                "tiles_used": int(acc.tiles_used_max),
                "per_mode": {
                    m: {
                        "violation_rate": b[0] / b[1] if b[1] else float("nan"),
                        "p99_s": b[2] / b[3] if b[3] else float("nan"),
                        "realloc_frac": b[4] / b[5] if b[5] else float("nan"),
                    }
                    for m, b in sorted(acc.per_mode.items())
                },
            }
            if acc.att_n:
                out[pol]["attribution"] = {
                    "n_recorded": acc.att_n,
                    "n_late": acc.att_late,
                    "n_dropped": acc.att_dropped,
                    "n_degraded": acc.att_degraded,
                    "lateness_s": acc.att_lateness,
                    "components_s": dict(acc.att_components),
                }
        return out
