"""On-disk content-addressed result cache for sweep cells.

Layout: ``<root>/<key[:2]>/<key>.json`` — one JSON sweep row per cell,
sharded by the first key byte so a million-cell fleet cache never puts
a million entries in one directory.  Writes are atomic (temp file +
``os.replace``), so concurrent shard workers on a shared filesystem
can populate the same cache without coordination: the worst case of a
racing double-write is the same bytes winning twice.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Dict, Iterator, Optional

__all__ = ["ResultCache"]


def _jsonable(obj):
    """Fallback encoder for row values: numpy scalars (which can leak
    out of report statistics) serialize as their Python equivalents;
    anything else is a real error."""
    item = getattr(obj, "item", None)
    if callable(item):
        return item()
    raise TypeError(
        f"sweep row value of type {type(obj).__name__!r} is not JSON-able"
    )


class ResultCache:
    """Content-addressed store of sweep rows, keyed by
    :func:`~repro.sweeps.cellkey.cell_key` digests."""

    def __init__(self, root) -> None:
        self.root = Path(root)
        self.hits = 0
        self.misses = 0
        self.puts = 0

    def path_for(self, key: str) -> Path:
        self._check_key(key)
        return self.root / key[:2] / f"{key}.json"

    def relative_path(self, key: str) -> str:
        """Cache-relative path recorded in campaign manifests."""
        return f"{key[:2]}/{key}.json"

    @staticmethod
    def _check_key(key: str) -> None:
        if len(key) < 8 or any(c not in "0123456789abcdef" for c in key):
            raise ValueError(f"not a cell key: {key!r}")

    def __contains__(self, key: str) -> bool:
        return self.path_for(key).exists()

    def get(self, key: str) -> Optional[Dict[str, object]]:
        """The cached row, or ``None``.  An unreadable/corrupt entry
        counts as a miss (the cell simply re-executes and the entry is
        rewritten) rather than poisoning the campaign."""
        path = self.path_for(key)
        try:
            with open(path, "r", encoding="utf-8") as fh:
                row = json.load(fh)
        except (OSError, ValueError):
            self.misses += 1
            return None
        self.hits += 1
        return row

    def put(self, key: str, row: Dict[str, object]) -> Path:
        path = self.path_for(key)
        path.parent.mkdir(parents=True, exist_ok=True)
        blob = json.dumps(row, default=_jsonable)
        fd, tmp = tempfile.mkstemp(
            dir=str(path.parent), prefix=f".{key[:8]}-", suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as fh:
                fh.write(blob)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.puts += 1
        return path

    def keys(self) -> Iterator[str]:
        if not self.root.is_dir():
            return
        for shard in sorted(self.root.iterdir()):
            if shard.is_dir() and len(shard.name) == 2:
                for f in sorted(shard.glob("*.json")):
                    yield f.stem

    def __len__(self) -> int:
        return sum(1 for _ in self.keys())

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses, "puts": self.puts}

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"ResultCache({str(self.root)!r}, {self.stats})"
